"""Distributed farm + compression tests. Multi-device paths run in a
subprocess with XLA_FLAGS host-device override so the main test process keeps
seeing exactly 1 device (required by the dry-run contract)."""
import subprocess
import sys
import textwrap

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.scrub import numpy_blank
from repro.distributed import (
    CompressionState,
    ElasticFarmController,
    ScrubFarm,
    bucket_by_resolution,
    int8_compress,
    int8_decompress,
    topk_compress,
    topk_decompress,
)
from repro.core import DeidPipeline
from repro.dicom.generator import StudyGenerator


class TestScrubFarmSingleDevice:
    def test_matches_reference(self, rng):
        farm = ScrubFarm()
        imgs = (rng.random((5, 64, 96)) * 4000).astype(np.uint16)
        rl = [[(0, 0, 96, 8)], [(10, 10, 20, 20)], [], [(90, 60, 20, 20)], [(0, 0, 1, 1)]]
        out = farm.scrub_batch(imgs, rl)
        ref = np.stack([numpy_blank(imgs[i], rl[i]) for i in range(5)])
        np.testing.assert_array_equal(out, ref)

    def test_batch_not_divisible_by_mesh(self, rng):
        farm = ScrubFarm()
        imgs = (rng.random((3, 32, 128)) * 250).astype(np.uint8)
        out = farm.scrub_batch(imgs, [[(0, 0, 128, 4)]] * 3)
        assert out.shape == imgs.shape
        assert (out[:, :4, :] == 0).all()

    def test_process_datasets_buckets_and_writes_back(self, gen):
        pipe = DeidPipeline(recompress=False)
        studies = [
            gen.gen_study("DF-1", modality="US", n_images=2),
            gen.gen_study("DF-2", modality="CT", n_images=2),
        ]
        datasets = [d for s in studies for d in s.datasets]
        buckets = bucket_by_resolution(datasets)
        assert len(buckets) == 2  # US and CT resolutions differ
        farm = ScrubFarm()
        applied = farm.process_datasets(datasets, pipe.scrub.rects_for)
        for i, rects in applied.items():
            for x, y, w, h in rects:
                H, W = datasets[i].pixels.shape
                assert (datasets[i].pixels[y : y + h, x : x + w] == 0).all()


class TestElasticController:
    def test_reconcile_resizes(self):
        c = ElasticFarmController()
        farm = c.reconcile(4)
        assert c.active == 1  # only 1 real device in-process
        assert farm is c.reconcile(4)  # no rebuild when stable
        assert c.rebuilds == 1

    def test_mark_failed_single_device_pool(self):
        c = ElasticFarmController()
        c.reconcile(1)
        # failing the only device leaves an empty healthy set; controller
        # must keep a 1-device mesh rather than dying (operator alert path)
        c.mark_failed(0)
        kinds = [e.kind for e in c.events]
        assert "device-failure" in kinds and "alert" in kinds
        assert c.reconcile(4) is not None  # still returns a farm handle


MULTIDEV_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    from repro.core import DeidPipeline
    from repro.core.scrub import numpy_blank
    from repro.distributed import ElasticFarmController, ScrubFarm

    assert len(jax.devices()) == 8
    rng = np.random.default_rng(0)
    imgs = (rng.random((13, 64, 128)) * 4000).astype(np.uint16)
    rl = [[(0, 0, 128, 8), (int(rng.integers(100)), int(rng.integers(50)), 20, 10)] for _ in range(13)]

    farm = ScrubFarm()
    assert farm.n == 8
    out = farm.scrub_batch(imgs, rl)
    ref = np.stack([numpy_blank(imgs[i], rl[i]) for i in range(13)])
    np.testing.assert_array_equal(out, ref)
    print("8-device farm OK")

    # elastic: shrink, grow, survive device failure
    c = ElasticFarmController()
    f4 = c.reconcile(4); assert c.active == 4
    out4 = f4.scrub_batch(imgs, rl)
    np.testing.assert_array_equal(out4, ref)
    f8 = c.reconcile(8); assert c.active == 8
    c.mark_failed(3)
    f_after = c.reconcile(8)
    assert c.active == 7, c.active
    out7 = f_after.scrub_batch(imgs, rl)
    np.testing.assert_array_equal(out7, ref)
    print("elastic re-mesh OK", c.rebuilds, "rebuilds")
    """
)


@pytest.mark.slow
def test_multidevice_farm_subprocess():
    proc = subprocess.run(
        [sys.executable, "-c", MULTIDEV_SCRIPT],
        capture_output=True,
        text=True,
        timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"},
        cwd=".",
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "8-device farm OK" in proc.stdout
    assert "elastic re-mesh OK" in proc.stdout


class TestCompression:
    def test_int8_roundtrip_accuracy(self, rng):
        g = jnp.asarray(rng.normal(size=(128, 64)).astype(np.float32))
        q, scale, st = int8_compress(g, CompressionState.init(g.shape))
        deq = int8_decompress(q, scale)
        assert q.dtype == jnp.int8
        # quantization error bounded by scale/2 per element
        assert float(jnp.max(jnp.abs(deq - g))) <= float(scale) * 0.5 + 1e-6

    def test_error_feedback_accumulates(self, rng):
        # with error feedback, the *sum* of dequantized grads tracks the sum
        # of true grads much better than independent quantization
        g = jnp.asarray(rng.normal(size=(1000,)).astype(np.float32)) * 1e-3
        st = CompressionState.init(g.shape)
        total = jnp.zeros_like(g)
        for _ in range(50):
            q, s, st = int8_compress(g, st)
            total = total + int8_decompress(q, s)
        err_ef = float(jnp.linalg.norm(total - 50 * g)) / float(jnp.linalg.norm(50 * g))
        assert err_ef < 0.05, err_ef

    def test_topk_keeps_largest(self, rng):
        g = jnp.asarray(np.arange(100, dtype=np.float32) - 50)
        vals, idx, st = topk_compress(g, CompressionState.init(g.shape), k_frac=0.1)
        assert vals.shape == (10,)
        deq = topk_decompress(vals, idx, g.shape, g.size)
        # kept entries are exactly the largest-magnitude ones
        kept = set(np.asarray(idx).tolist())
        mags = np.abs(np.asarray(g))
        assert kept == set(np.argsort(-mags)[:10].tolist())
        # residual holds what was dropped
        np.testing.assert_allclose(np.asarray(st.residual + deq), np.asarray(g), atol=1e-6)

    def test_topk_error_feedback_recovers_small_coords(self, rng):
        g = jnp.asarray(rng.normal(size=(256,)).astype(np.float32))
        st = CompressionState.init(g.shape)
        total = jnp.zeros_like(g)
        for _ in range(200):
            vals, idx, st = topk_compress(g, st, k_frac=0.05)
            total = total + topk_decompress(vals, idx, g.shape, g.size)
        err = float(jnp.linalg.norm(total / 200 - g)) / float(jnp.linalg.norm(g))
        assert err < 0.1, err
