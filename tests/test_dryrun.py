"""Dry-run machinery unit tests: HLO analyzer, cell matrix, sharding rules.
(The real 512-device dry-run runs via launch/dryrun.py; here we validate the
pieces on the single-device smoke mesh + synthetic HLO.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.config.model import SHAPES, cell_runnable
from repro.config.registry import get_arch, list_archs
from repro.launch.hlo_analysis import analyze_hlo, split_computations, _trip_count
from repro.launch.mesh import make_smoke_mesh, mesh_info
from repro.launch.shardings import fsdp_pspec, logical_rules, spec_to_pspec
from repro.models import build_model
from repro.models.spec import TensorSpec

SYNTHETIC_HLO = """
HloModule test

%body.1 (p: (s32[], f32[16,128])) -> (s32[], f32[16,128]) {
  %p = (s32[], f32[16,128]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[16,128]{1,0} get-tuple-element(%p), index=1
  %w = f32[128,128]{1,0} constant({...})
  %d = f32[16,128]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[16,128]{1,0} all-reduce(%d), replica_groups=[16,16]<=[256], to_apply=%add.1
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[16,128]) tuple(%ni, %ar)
}

%cond.1 (p: (s32[], f32[16,128])) -> pred[] {
  %p = (s32[], f32[16,128]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(24)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main.1 (a: f32[16,128]) -> f32[16,128] {
  %a = f32[16,128]{1,0} parameter(0)
  %z = s32[] constant(0)
  %init = (s32[], f32[16,128]) tuple(%z, %a)
  %w = (s32[], f32[16,128]) while(%init), condition=%cond.1, body=%body.1
  ROOT %out = f32[16,128]{1,0} get-tuple-element(%w), index=1
}
"""


class TestHloAnalyzer:
    def test_while_trip_multiplication(self):
        res = analyze_hlo(SYNTHETIC_HLO)
        # dot: 2 * 16*128 * 128 flops, times 24 trips
        assert res["flops"] == pytest.approx(24 * 2 * 16 * 128 * 128)
        # all-reduce operand: 16*128*4 bytes times 24
        assert res["coll_total"] == pytest.approx(24 * 16 * 128 * 4)
        assert res["coll_cross"] == 0  # groups of 16 within one pod

    def test_split_and_trip(self):
        comps = split_computations(SYNTHETIC_HLO)
        assert {"body.1", "cond.1", "main.1"} <= set(comps)
        assert _trip_count(comps["cond.1"]) == 24

    def test_cross_pod_attribution(self):
        hlo = SYNTHETIC_HLO.replace(
            "replica_groups=[16,16]<=[256]", "replica_groups=[256,2]<=[2,256]T(1,0)"
        )
        res = analyze_hlo(hlo)
        assert res["coll_cross"] == pytest.approx(24 * 16 * 128 * 4)

    def test_real_program_flops_track_model_flops(self):
        """Tiny end-to-end check on the 1-device mesh: analyzer flops within
        2x of the analytic 6ND for a reduced dense model train step."""
        from repro.training import cosine_schedule, make_train_step, train_state_init

        cfg = get_arch("qwen2-0.5b").reduced()
        model = build_model(cfg)
        state = train_state_init(model, jax.random.PRNGKey(0))
        B, S = 2, 128
        batch = {
            "tokens": jnp.zeros((B, S), jnp.int32),
            "labels": jnp.zeros((B, S), jnp.int32),
        }
        step = make_train_step(model, cosine_schedule(1e-3, 0, 10))
        compiled = jax.jit(step).lower(state, batch).compile()
        res = analyze_hlo(compiled.as_text())
        from repro.models.spec import param_count

        n = param_count(model.param_specs())
        model_flops = 6 * n * B * S
        assert 0.5 < res["flops"] / model_flops < 3.0, (res["flops"], model_flops)


class TestCellMatrix:
    def test_40_cells_with_spec_skips(self):
        cells = [(a, s) for a in list_archs() for s in SHAPES]
        assert len(cells) == 40
        runnable = [(a, s) for a, s in cells if cell_runnable(get_arch(a), SHAPES[s])[0]]
        skipped = [(a, s) for a, s in cells if not cell_runnable(get_arch(a), SHAPES[s])[0]]
        assert len(runnable) == 31
        # encoder: no decode cells
        assert ("hubert-xlarge", "decode_32k") in skipped
        assert ("hubert-xlarge", "long_500k") in skipped
        # long_500k only for ssm/hybrid
        assert ("zamba2-2.7b", "long_500k") in runnable
        assert ("falcon-mamba-7b", "long_500k") in runnable
        assert ("qwen1.5-110b", "long_500k") in skipped

    def test_skip_reasons_documented(self):
        ok, reason = cell_runnable(get_arch("qwen1.5-110b"), SHAPES["long_500k"])
        assert not ok and "sub-quadratic" in reason
        ok, reason = cell_runnable(get_arch("hubert-xlarge"), SHAPES["decode_32k"])
        assert not ok and "encoder" in reason


class TestShardingRules:
    def test_smoke_mesh_has_production_axes(self):
        mesh = make_smoke_mesh()
        info = mesh_info(mesh)
        assert set(info["axes"]) == {"data", "model"}
        assert not info["multi_pod"]

    def test_moe_rules_divisibility(self):
        mesh = make_smoke_mesh()
        # force tp=16 semantics by checking against arch config directly
        olmoe, mixtral = get_arch("olmoe-1b-7b"), get_arch("mixtral-8x22b")

        class FakeMesh:
            shape = {"model": 16, "data": 16}
            axis_names = ("data", "model")

        r_olmoe = logical_rules(olmoe, FakeMesh())
        r_mixtral = logical_rules(mixtral, FakeMesh())
        assert r_olmoe["experts"] == "model" and r_olmoe["mlp"] is None
        assert r_mixtral["experts"] is None and r_mixtral["mlp"] == "model"

    def test_fsdp_pspec_shards_large_tensors_only(self):
        class FakeMesh:
            shape = {"model": 16, "data": 16}
            axis_names = ("data", "model")

        rules = {"embed": None, "heads": "model"}
        big = TensorSpec((4096, 4096), ("embed", "heads"))
        small = TensorSpec((4096,), ("embed",))
        assert fsdp_pspec(big, rules, FakeMesh()) == P("data", "model")
        assert fsdp_pspec(small, rules, FakeMesh()) == P(None)

    @pytest.mark.parametrize("arch", list_archs())
    def test_param_pspecs_never_reuse_axis(self, arch):
        """No tensor may map the same mesh axis twice (GSPMD error)."""
        class FakeMesh:
            shape = {"model": 16, "data": 16}
            axis_names = ("data", "model")

        cfg = get_arch(arch)
        rules = logical_rules(cfg, FakeMesh())
        model = build_model(cfg)
        specs = jax.tree.leaves(
            model.param_specs(), is_leaf=lambda x: isinstance(x, TensorSpec)
        )
        for s in specs:
            spec = fsdp_pspec(s, rules, FakeMesh())
            flat = [a for a in spec if a is not None]
            assert len(flat) == len(set(flat)), (arch, s, spec)
