"""Split Golomb-Rice codec (plan/pack) + the Pallas entropy pre-pass
(DESIGN.md §12): byte-identity against the legacy bit-array encoder,
round-trip properties for the vectorized decoder (escapes included), and
plan parity between the host and device pre-pass paths."""
import numpy as np
import pytest

from repro.dicom import codec

_QMAX = 23


# --- legacy encoder (pre-split, bit-array construction), kept verbatim as the
# --- byte-identity oracle for the word-level packer
def _legacy_rice_k(u):
    k = 0
    while (1 << k) < u.mean() + 1 and k < 30:
        k += 1
    return k


def _legacy_rice_encode(res):
    u = codec._zigzag(res.ravel())
    k = _legacy_rice_k(u)
    q = (u >> k).astype(np.int64)
    rem = (u & ((1 << k) - 1)).astype(np.uint64)
    esc = q > _QMAX
    lens = np.where(esc, _QMAX + 2 + 64, q + 1 + k)
    offs = np.concatenate([[0], np.cumsum(lens)])
    total = int(offs[-1])
    bits = np.zeros(total, np.uint8)
    delta = np.zeros(total + 1, np.int32)
    q_eff = np.where(esc, _QMAX + 1, q)
    nz = q_eff > 0
    np.add.at(delta, offs[:-1][nz], 1)
    np.add.at(delta, (offs[:-1] + q_eff)[nz], -1)
    bits[np.cumsum(delta[:-1]) > 0] = 1
    if k and (~esc).any():
        base = (offs[:-1] + q + 1)[~esc]
        rne = rem[~esc]
        for j in range(k):
            bits[base + j] = (rne >> np.uint64(k - 1 - j)) & np.uint64(1)
    for idx in np.flatnonzero(esc):
        base = int(offs[idx]) + _QMAX + 2
        val = int(u[idx])
        for j in range(64):
            bits[base + j] = (val >> (63 - j)) & 1
    return np.packbits(bits).tobytes(), k


def _cases(rng):
    yield (rng.normal(128, 40, size=(64, 80))).clip(0, 255).astype(np.uint8)
    yield (rng.normal(2048, 600, size=(96, 64))).clip(0, 4095).astype(np.uint16)
    yield np.zeros((32, 32), np.uint8)  # k=0, all-zero residual tail
    smooth = np.tile(np.arange(48, dtype=np.uint16) * 9, (40, 1))
    yield smooth  # highly predictable -> tiny k


class TestPackByteIdentity:
    @pytest.mark.parametrize("sv", [1, 2, 5, 7])
    def test_plan_pack_equals_legacy_bitarray(self, rng, sv):
        for img in _cases(rng):
            res = codec.residuals(img, sv)
            legacy_payload, legacy_k = _legacy_rice_encode(res)
            payload, k = codec.rice_encode(res)
            assert k == legacy_k
            assert payload == legacy_payload

    def test_escape_heavy_stream_byte_identical(self, rng):
        # mostly-zero residuals + huge outliers force k=0 with q > QMAX escapes
        res = np.zeros(4096, np.int64)
        hot = rng.choice(4096, size=37, replace=False)
        res[hot] = rng.integers(-(2**20), 2**20, size=37)
        assert (codec.rice_plan(res).esc).sum() > 0  # escapes actually present
        legacy_payload, legacy_k = _legacy_rice_encode(res)
        payload, k = codec.rice_encode(res)
        assert (payload, k) == (legacy_payload, legacy_k)

    def test_plan_total_bits_matches_payload_length(self, rng):
        res = codec.residuals((rng.random((50, 60)) * 4095).astype(np.uint16), 2)
        plan = codec.rice_plan(res)
        payload = codec.rice_pack(plan)
        assert len(payload) == (plan.total_bits + 7) // 8

    def test_encode_header_roundtrip_unchanged(self, rng):
        img = (rng.random((40, 56)) * 255).astype(np.uint8)
        stream = codec.encode(img, sv=3)
        assert np.array_equal(codec.decode(stream), img)


class TestVectorizedDecode:
    @pytest.mark.parametrize("sv", [1, 3, 7])
    def test_roundtrip_images(self, rng, sv):
        for img in _cases(rng):
            res = codec.residuals(img, sv)
            payload, k = codec.rice_encode(res)
            got = codec.rice_decode(payload, k, res.size)
            np.testing.assert_array_equal(got, res.ravel())

    def test_roundtrip_with_escapes_falls_back(self, rng):
        res = np.zeros(2048, np.int64)
        res[rng.choice(2048, size=19, replace=False)] = rng.integers(
            -(2**22), 2**22, size=19
        )
        payload, k = codec.rice_encode(res)
        assert (codec.rice_plan(res).esc).sum() > 0
        np.testing.assert_array_equal(codec.rice_decode(payload, k, 2048), res)

    def test_roundtrip_k_zero_and_empty(self):
        res = np.zeros(100, np.int64)
        payload, k = codec.rice_encode(res)
        assert k == 0
        np.testing.assert_array_equal(codec.rice_decode(payload, k, 100), res)
        assert codec.rice_decode(b"", 0, 0).size == 0

    def test_roundtrip_every_small_k(self, rng):
        # pin k by construction: residual magnitudes ~ 2^k keep q small
        for k_target in range(0, 12):
            mags = rng.integers(0, 2 ** (k_target + 1), size=512)
            res = ((mags + 1) // 2) * np.where(mags % 2 == 0, 1, -1)
            payload, k = codec.rice_encode(res)
            np.testing.assert_array_equal(codec.rice_decode(payload, k, 512), res)

    def test_exact_sum_k_matches_mean_k(self, rng):
        # the device path derives k from exact integer row sums; it must land
        # on the same parameter as the float-mean legacy rule
        for img in _cases(rng):
            u = codec._zigzag(codec.residuals(img, 2).ravel())
            assert codec._rice_k(u) == _legacy_rice_k(u)
            assert codec._rice_k(u) == codec._rice_k_from_sum(
                int(u.sum(dtype=np.uint64)), u.size
            )


class TestResidualsBatch:
    @pytest.mark.parametrize("sv", [1, 4, 7])
    def test_bit_identical_to_per_plane(self, rng, sv):
        imgs = (rng.random((5, 33, 47)) * 4095).astype(np.uint16)
        batched = codec.residuals_batch(imgs, sv)
        for j in range(5):
            np.testing.assert_array_equal(batched[j], codec.residuals(imgs[j], sv))

    def test_rejects_non_stack(self, rng):
        with pytest.raises(ValueError):
            codec.residuals_batch(np.zeros((8, 8), np.uint8))


class TestDevicePrepass:
    """Pallas zigzag/rowsum + length/remainder kernels (interpret mode on CPU)
    must reproduce the host plan bit-exactly — same k, lens, offsets, bytes."""

    def _device_plans(self, res_batch):
        from repro.kernels.jls import entropy

        N, H, W = res_batch.shape
        u_d, rs_d = entropy.rice_prepass(res_batch.astype(np.int32), bh=16)
        rs = np.asarray(rs_d)
        ks = np.array(
            [codec._rice_k_from_sum(int(rs[j].sum()), H * W) for j in range(N)],
            np.int32,
        )
        lens_d, rem_d = entropy.rice_len_rem(u_d, ks, bh=16)
        u_np = np.asarray(u_d).reshape(N, -1)
        lens_np, rem_np = np.asarray(lens_d), np.asarray(rem_d)
        return [
            codec.rice_plan_from_prepass(u_np[j], int(ks[j]), lens_np[j], rem_np[j])
            for j in range(N)
        ]

    def test_qmax_constant_pinned(self):
        from repro.kernels.jls import entropy

        assert entropy._QMAX == codec._QMAX == _QMAX
        assert entropy._ESC_LEN == _QMAX + 2 + 64

    @pytest.mark.parametrize("sv", [1, 3])
    def test_prepass_plan_and_bytes_match_host(self, rng, sv):
        imgs = (rng.normal(900, 300, size=(3, 48, 40))).clip(0, 4095).astype(np.uint16)
        res = codec.residuals_batch(imgs, sv)
        for plan_d, j in zip(self._device_plans(res), range(3)):
            plan_h = codec.rice_plan(res[j])
            assert plan_d.k == plan_h.k
            np.testing.assert_array_equal(plan_d.lens, plan_h.lens)
            np.testing.assert_array_equal(plan_d.offs, plan_h.offs)
            assert codec.rice_pack(plan_d) == codec.rice_pack(plan_h)

    def test_prepass_escape_lengths_match_host(self, rng):
        # outlier residuals whose q exceeds QMAX at the chosen k
        res = np.zeros((2, 32, 32), np.int64)
        res[0, 3, 5] = 2**16
        res[1, 10, 2] = -(2**15)
        plans_d = self._device_plans(res)
        for j in range(2):
            plan_h = codec.rice_plan(res[j])
            assert plan_h.esc.sum() > 0
            np.testing.assert_array_equal(plans_d[j].lens, plan_h.lens)
            assert codec.rice_pack(plans_d[j]) == codec.rice_pack(plan_h)

    def test_non_multiple_block_height_padding(self, rng):
        # H=20 with bh=16 exercises the pad/crop path in both kernels
        imgs = (rng.random((2, 20, 24)) * 255).astype(np.uint8)
        res = codec.residuals_batch(imgs, 1)
        for plan_d, j in zip(self._device_plans(res), range(2)):
            assert codec.rice_pack(plan_d) == codec.rice_pack(codec.rice_plan(res[j]))
