"""Fused scrub+JLS kernel vs the staged two-pass oracle.

The fused kernel must be bit-exact against ``numpy_blank -> codec.residuals``
(the host pair) and against the jnp staged composition, across dtypes, all
selection values, and adversarial rect lists (empty, overlapping,
out-of-bounds, negative origins)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.scrub import numpy_blank
from repro.dicom import codec
from repro.kernels.fused.ops import fused_encode_batch, fused_scrub_residuals
from repro.kernels.fused.ref import fused_ref
from repro.kernels.jls.ops import jls_residuals
from repro.kernels.scrub.ops import pack_rects


def _oracle(imgs: np.ndarray, rect_lists, sv: int) -> np.ndarray:
    """The staged host pair: blank, then predictor residuals."""
    return np.stack(
        [codec.residuals(numpy_blank(imgs[i], rect_lists[i]), sv) for i in range(imgs.shape[0])]
    )


def _rand_imgs(rng, shape, dtype):
    maxv = 255 if dtype == np.uint8 else 4095
    return (rng.random(shape) * maxv).astype(dtype)


RECT_CASES = {
    "empty": lambda H, W: [],
    "banner": lambda H, W: [(0, 0, W, max(1, H // 8))],
    "overlapping": lambda H, W: [(2, 2, W // 2, H // 2), (W // 4, H // 4, W // 2, H // 2)],
    "out_of_bounds": lambda H, W: [(W - 5, H - 5, 99, 99), (-7, -3, 15, 12)],
    "degenerate": lambda H, W: [(5, 5, 0, 10), (5, 5, 10, 0), (0, 0, 0, 0)],
    "full_frame": lambda H, W: [(0, 0, W, H)],
}


class TestFusedKernel:
    @pytest.mark.parametrize("sv", list(range(1, 8)))
    @pytest.mark.parametrize("dtype", [np.uint8, np.uint16])
    def test_all_sv_and_dtypes(self, rng, sv, dtype):
        imgs = _rand_imgs(rng, (2, 70, 90), dtype)
        rl = [[(5, 5, 30, 20), (0, 0, 90, 8)], [(40, 30, 200, 200)]]
        rects = pack_rects(rl)
        got = np.asarray(fused_scrub_residuals(imgs, rects, sv=sv))
        np.testing.assert_array_equal(got, _oracle(imgs, rl, sv))

    @pytest.mark.parametrize("case", sorted(RECT_CASES))
    def test_rect_classes(self, rng, case):
        H, W = 60, 100
        imgs = _rand_imgs(rng, (2, H, W), np.uint16)
        rl = [RECT_CASES[case](H, W)] * 2
        rects = pack_rects(rl)
        got = np.asarray(fused_scrub_residuals(imgs, rects, sv=4))
        np.testing.assert_array_equal(got, _oracle(imgs, rl, 4))

    def test_property_sweep_random(self, rng):
        """Randomized property: fused == blank->residuals for random shapes,
        dtypes, sv, and rect lists (the hypothesis-style sweep, seeded)."""
        for trial in range(12):
            N = int(rng.integers(1, 4))
            H = int(rng.integers(8, 140))
            W = int(rng.integers(8, 200))
            dtype = [np.uint8, np.uint16][trial % 2]
            sv = int(rng.integers(1, 8))
            imgs = _rand_imgs(rng, (N, H, W), dtype)
            rl = []
            for _ in range(N):
                n_rects = int(rng.integers(0, 5))
                rl.append(
                    [
                        (
                            int(rng.integers(-20, W + 20)),
                            int(rng.integers(-20, H + 20)),
                            int(rng.integers(0, W + 40)),
                            int(rng.integers(0, H + 40)),
                        )
                        for _ in range(n_rects)
                    ]
                )
            rects = pack_rects(rl)
            got = np.asarray(fused_scrub_residuals(imgs, rects, sv=sv))
            np.testing.assert_array_equal(
                got, _oracle(imgs, rl, sv), err_msg=f"trial={trial} sv={sv} shape={(N, H, W)}"
            )

    def test_matches_jnp_staged_ref(self, rng):
        imgs = _rand_imgs(rng, (2, 64, 96), np.uint16)
        rl = [[(10, 10, 40, 20)], [(0, 0, 96, 6), (50, 30, 30, 30)]]
        rects = pack_rects(rl)
        got = np.asarray(fused_scrub_residuals(imgs, rects, sv=5))
        ref = np.asarray(fused_ref(jnp.asarray(imgs), jnp.asarray(rects), 5, 16))
        np.testing.assert_array_equal(got, ref)

    def test_no_rects_equals_plain_jls(self, rng):
        imgs = _rand_imgs(rng, (2, 64, 96), np.uint16)
        rects = np.zeros((2, 1, 4), np.int32)
        got = np.asarray(fused_scrub_residuals(imgs, rects, sv=2))
        np.testing.assert_array_equal(got, np.asarray(jls_residuals(imgs, sv=2)))

    def test_blanked_neighbors_feed_prediction(self, rng):
        """The fusion hinge: pixels bordering a rect must be predicted from
        the *blanked* neighbor values, as if the scrubbed image had been
        materialized. With sv=1 (predict = left), the pixel just right of a
        blanked rect must carry its full value as residual."""
        img = np.full((1, 32, 64), 100, np.uint16)
        rl = [[(8, 8, 16, 16)]]
        res = np.asarray(fused_scrub_residuals(img, pack_rects(rl), sv=1))[0]
        assert (res[8:24, 24] == 100).all()  # left neighbor is blanked -> 100 - 0
        assert (res[8:24, 25] == 0).all()    # interior of untouched region

    def test_stripe_boundary_rect(self, rng):
        """Rect edge exactly on a bh stripe boundary: the above-neighbor of
        the first row of a stripe comes from the previous stripe's masked row."""
        imgs = _rand_imgs(rng, (1, 128, 64), np.uint16)
        for rl in ([[(0, 48, 64, 16)]], [[(10, 63, 30, 2)]], [[(0, 0, 64, 64)]]):
            rects = pack_rects(rl)
            got = np.asarray(fused_scrub_residuals(imgs, rects, sv=2, bh=64))
            np.testing.assert_array_equal(got, _oracle(imgs, rl, 2))

    def test_roundtrip_through_codec(self, rng):
        """Residuals from the fused kernel decode back to the blanked image."""
        imgs = _rand_imgs(rng, (1, 40, 56), np.uint8)
        rl = [[(4, 4, 20, 10)]]
        res = np.asarray(fused_scrub_residuals(imgs, pack_rects(rl), sv=1))[0]
        out = codec.reconstruct(res, sv=1, bits=8)
        np.testing.assert_array_equal(out, numpy_blank(imgs[0], rl[0]))


class TestFusedEncode:
    def test_byte_identical_to_host_encode(self, rng):
        imgs = _rand_imgs(rng, (3, 48, 64), np.uint16)
        rl = [[(3, 3, 20, 10)], [], [(0, 0, 64, 48)]]
        bufs = fused_encode_batch(imgs, rl, sv=1)
        for i in range(3):
            want = codec.encode(numpy_blank(imgs[i], rl[i]), 1)
            assert bufs[i] == want, i
            np.testing.assert_array_equal(codec.decode(bufs[i]), numpy_blank(imgs[i], rl[i]))
