"""Change-feed ingest: feed, checkpoint, pooler/applier, stale-byte fencing.

Covers the DESIGN.md §10 contract end to end at the unit level:

* the simulated PACS emits a monotonic change sequence with deterministic
  delivery faults;
* the checkpoint is durable and crash-replayable (torn tails repaired);
* pooler handoff is at-least-once and the applier is effect-idempotent, so
  crashes, duplicates, and out-of-order delivery all net to exactly-once;
* workers fence stale reads and abort zombie work instead of delivering
  pre-mutation bytes (with the fence-off negative control).
"""
import json

import pytest

from repro.catalog import StudyCatalog
from repro.core import DeidPipeline, TrustMode
from repro.dicom.generator import StudyGenerator
from repro.ingest import (
    ChangePooler,
    Checkpoint,
    FeedOutage,
    IngestApplier,
    PacsFeed,
    PoolerCrash,
    seeded_mutations,
)
from repro.lake.store import ResultLake
from repro.queueing.autoscaler import Autoscaler, AutoscalerConfig
from repro.queueing.broker import Broker
from repro.queueing.journal import Journal
from repro.queueing.server import DeidService
from repro.queueing.worker import DeidWorker, WorkerPool
from repro.storage.object_store import StudyStore
from repro.utils.timing import SimClock


def _feed_env(tmp_path, name="ckpt", seed=3, n_initial=3, **pooler_kw):
    """Lake + catalog + feed (initial corpus adopted) + pooler/applier pair."""
    clock = SimClock()
    feed = PacsFeed(seed)
    gen = StudyGenerator(seed)
    store = StudyStore("lake", key=b"k")
    catalog = StudyCatalog()
    store.attach_catalog(catalog)
    for i in range(n_initial):
        acc = f"ACC{i:04d}"
        s = gen.gen_study(acc, modality="CT", n_images=2)
        store.put_study(acc, s)
        feed.adopt(acc, s)
    broker = Broker(clock, visibility_timeout=60.0)
    ckpt = Checkpoint(tmp_path / f"{name}.jsonl")
    pooler = ChangePooler(feed, broker, ckpt, clock, seed=seed, **pooler_kw)
    applier = IngestApplier(broker, feed, store, ckpt)
    return clock, feed, store, catalog, broker, ckpt, pooler, applier


def _drain(clock, pooler, applier, broker, step=30.0, max_rounds=200):
    for _ in range(max_rounds):
        if not pooler.behind() and broker.empty():
            break
        wake = max(pooler.next_poll_at, pooler.breaker_open_until or 0.0)
        if wake > clock.now():
            clock.advance(wake - clock.now())
        pooler.poll_once()
        applier.drain()
        clock.advance(step)


# ------------------------------------------------------------------ the feed
class TestPacsFeed:
    def test_commit_monotonic_seq_and_versions(self):
        feed = PacsFeed(1)
        e1 = feed.commit("create", "A")
        e2 = feed.commit("update", "A")
        e3 = feed.commit("delete", "A")
        assert [e.seq for e in (e1, e2, e3)] == [1, 2, 3]
        assert e1.etag and e2.etag and e1.etag != e2.etag  # new bytes, new etag
        assert e3.etag == "" and feed.fetch("A") is None
        assert feed.commit("delete", "A") is None  # no-op: already gone
        assert feed.last_seq == 3

    def test_poll_cursor_and_limit(self):
        feed = PacsFeed(1)
        for i in range(5):
            feed.commit("create", f"A{i}")
        assert [e.seq for e in feed.poll(0, limit=3)] == [1, 2, 3]
        assert [e.seq for e in feed.poll(3)] == [4, 5]
        assert feed.poll(5) == []

    def test_outage_raises(self):
        feed = PacsFeed(1)
        feed.commit("create", "A")
        feed.outage = True
        with pytest.raises(FeedOutage):
            feed.poll(0)
        feed.outage = False
        assert len(feed.poll(0)) == 1

    def test_delivery_faults_are_deterministic(self):
        def build():
            f = PacsFeed(9)
            for i in range(6):
                f.commit("create", f"A{i}")
            f.dup_rate, f.shuffle = 0.5, True
            return [f.poll(0) for _ in range(3)]

        assert build() == build()

    def test_seeded_mutations_deterministic_and_delete_safe(self):
        corpus = [f"ACC{i}" for i in range(4)]
        m1 = seeded_mutations(5, 600.0, corpus, 20)
        assert m1 == seeded_mutations(5, 600.0, corpus, 20)
        assert m1 != seeded_mutations(6, 600.0, corpus, 20)
        ts = [m.t for m in m1]
        assert ts == sorted(ts) and len(set(ts)) == len(ts)
        created = {m.accession for m in m1 if m.op == "create"}
        for m in m1:
            if m.op == "delete":  # never deletes the pre-feed corpus
                assert m.accession in created


# -------------------------------------------------------------- the checkpoint
class TestCheckpoint:
    def test_floor_is_largest_contiguous_seen(self, tmp_path):
        ck = Checkpoint(tmp_path / "c.jsonl")
        for s in (1, 2, 5):
            ck.mark_seen(s)
        assert ck.floor() == 2  # 5 is deduped in memory, not part of the floor
        ck.mark_seen(3)
        ck.mark_seen(4)
        assert ck.floor() == 5
        ck.close()

    def test_replay_restores_state(self, tmp_path):
        p = tmp_path / "c.jsonl"
        ck = Checkpoint(p)
        ck.mark_seen(1)
        ck.mark_seen(2)
        ck.mark_outcome(1, "A", "e1", "create", "applied", rows=2)
        ck.mark_outcome(2, "A", "e1", "update", "dup")
        ck.close()
        ck2 = Checkpoint(p)
        assert ck2.floor() == 2 and ck2.seen == {1, 2}
        assert ck2.has_outcome(1) and ck2.has_outcome(2)
        assert ck2.applied_etag == {"A": "e1"} and ck2.applied_seq == {"A": 1}
        assert ck2.double_applied == []
        ck2.close()

    def test_torn_tail_is_repaired(self, tmp_path):
        p = tmp_path / "c.jsonl"
        ck = Checkpoint(p)
        ck.mark_seen(1)
        ck.close()
        with open(p, "ab") as fh:  # crash mid-append: partial record, no newline
            fh.write(b'{"kind": "seen", "se')
        ck2 = Checkpoint(p)
        assert ck2.torn_tail == 1 and ck2.seen == {1}
        ck2.mark_seen(2)  # file must be line-aligned again after the repair
        ck2.close()
        ck3 = Checkpoint(p)
        assert ck3.seen == {1, 2} and ck3.torn_tail == 0
        ck3.close()

    def test_complete_tail_missing_newline_is_absorbed(self, tmp_path):
        p = tmp_path / "c.jsonl"
        ck = Checkpoint(p)
        ck.mark_seen(1)
        ck.close()
        with open(p, "ab") as fh:  # full record, crash before the newline
            fh.write(json.dumps({"kind": "seen", "seq": 2}).encode())
        ck2 = Checkpoint(p)
        assert ck2.seen == {1, 2} and ck2.floor() == 2 and ck2.torn_tail == 0
        ck2.close()

    def test_duplicate_outcome_in_file_is_surfaced(self, tmp_path):
        p = tmp_path / "c.jsonl"
        ck = Checkpoint(p)
        ck.mark_outcome(1, "A", "e1", "update", "applied")
        ck._append({"kind": "op", "seq": 1, "accession": "A", "etag": "e1",
                    "op": "update", "outcome": "applied", "rows": 0})
        ck.close()
        ck2 = Checkpoint(p)
        assert ck2.double_applied == [1]  # the monotonicity checker's hook
        ck2.close()


# ------------------------------------------------------- pooler/applier plane
class TestPoolerHandoff:
    def test_drain_lands_every_mutation_exactly_once(self, tmp_path):
        clock, feed, store, catalog, broker, ckpt, pooler, applier = _feed_env(
            tmp_path
        )
        feed.commit("create", "PACS0")
        feed.commit("update", "ACC0001")
        feed.commit("create", "PACS1")
        _drain(clock, pooler, applier, broker)
        assert store.has_study("PACS0")
        feed.commit("delete", "PACS0")  # delete lands after the create applied
        _drain(clock, pooler, applier, broker)
        assert not pooler.behind() and broker.empty()
        assert sorted(store.accessions()) == sorted(feed.accessions())
        assert not store.has_study("PACS0")
        assert set(ckpt.outcomes) == {1, 2, 3, 4}
        assert ckpt.double_applied == []
        # catalog followed the deltas: delete tombstoned, update re-ingested
        assert catalog.stats.deletes == 1
        assert "PACS1" in catalog.accessions()
        assert "PACS0" not in catalog.accessions()

    def test_duplicates_and_out_of_order_are_effect_idempotent(self, tmp_path):
        clock, feed, store, catalog, broker, ckpt, pooler, applier = _feed_env(
            tmp_path, name="faulty"
        )
        feed.dup_rate, feed.shuffle = 1.0, True  # worst-case transport
        for i in range(6):
            feed.commit("create", f"P{i}")
        _drain(clock, pooler, applier, broker)
        assert not pooler.behind() and broker.empty()
        assert set(ckpt.outcomes) == set(range(1, 7))
        assert ckpt.double_applied == []
        stats = applier.stats
        assert stats.applied == 6  # one effective apply per committed event
        assert pooler.stats.duplicates > 0  # the faults actually fired
        for i in range(6):
            assert store.has_study(f"P{i}")

    def test_update_burst_collapses_to_one_apply(self, tmp_path):
        clock, feed, store, catalog, broker, ckpt, pooler, applier = _feed_env(
            tmp_path, name="burst"
        )
        for _ in range(3):
            feed.commit("update", "ACC0000")
        pooler.poll_once()
        applier.drain()
        # the applier fetches *current* bytes: first event lands the final
        # version, the remaining two dedup against (accession, etag)
        assert applier.stats.applied == 1
        assert applier.stats.effect_deduped == 2
        assert store.study_etag("ACC0000") is not None

    def test_backoff_grows_then_breaker_opens_and_recovers(self, tmp_path):
        clock, feed, store, catalog, broker, ckpt, pooler, applier = _feed_env(
            tmp_path, name="outage", base_backoff=5.0,
            breaker_threshold=3, breaker_cooldown=120.0,
        )
        feed.commit("create", "P0")
        feed.outage = True
        backoffs = []
        for _ in range(3):
            wake = pooler.next_poll_at
            if wake > clock.now():
                clock.advance(wake - clock.now())
            status = pooler.poll_once()
            assert status.get("outage")
            backoffs.append(status["backoff"])
        assert backoffs[0] < backoffs[1] < backoffs[2]  # exponential + jitter
        assert pooler.stats.breaker_opens == 1
        assert pooler.breaker_open_until is not None
        # while open: polls are skipped entirely, the feed is never touched
        assert pooler.poll_once() == {
            "skipped": "breaker", "until": pooler.breaker_open_until
        }
        # after cooldown: half-open trial poll succeeds and closes the breaker
        feed.outage = False
        clock.advance(pooler.breaker_open_until - clock.now())
        status = pooler.poll_once()
        assert status.get("handed") == 1
        assert pooler.failures == 0 and pooler.breaker_open_until is None
        applier.drain()
        assert store.has_study("P0")

    def test_crash_mid_batch_resumes_from_checkpoint(self, tmp_path):
        clock, feed, store, catalog, broker, ckpt, pooler, applier = _feed_env(
            tmp_path, name="crash"
        )
        for i in range(5):
            feed.commit("create", f"P{i}")
        with pytest.raises(PoolerCrash):
            pooler.poll_once(crash_after=2)  # seq 3 published, never marked seen
        applier.drain()  # the pre-crash handoffs (and the orphan) still apply
        ckpt.close()
        # recovery: a fresh process replays the durable checkpoint
        ck2 = Checkpoint(tmp_path / "crash.jsonl")
        assert ck2.floor() == 2  # seqs 1-2 seen; 3 was published but not seen
        pooler2 = ChangePooler(feed, broker, ck2, clock, seed=3)
        applier2 = IngestApplier(broker, feed, store, ck2)
        _drain(clock, pooler2, applier2, broker)
        assert not pooler2.behind() and broker.empty()
        assert set(ck2.outcomes) == set(range(1, 6))
        assert ck2.double_applied == []
        # seq 3 was redelivered after the crash and deduped, never re-applied
        assert applier2.stats.redelivered >= 1
        for i in range(5):
            assert store.has_study(f"P{i}")

    def test_resume_equals_uninterrupted_run(self, tmp_path):
        base = self._run_with_crashes(tmp_path, "base", [])
        crashed = self._run_with_crashes(tmp_path, "crsh", [True, False, True])
        assert base == crashed

    @staticmethod
    def _run_with_crashes(tmp_path, name, crashes, seed=3):
        clock, feed, store, catalog, broker, ckpt, pooler, applier = _feed_env(
            tmp_path, name=name, seed=seed, batch=2
        )
        for mut in seeded_mutations(seed, 100.0, store.accessions(), 8):
            feed.commit(mut.op, mut.accession)
        path = ckpt.path
        for i in range(200):
            if not pooler.behind() and broker.empty():
                break
            crash = i < len(crashes) and crashes[i]
            try:
                pooler.poll_once(crash_after=0 if crash else None)
            except PoolerCrash:
                pooler.checkpoint.close()
                ck = Checkpoint(path)
                pooler = ChangePooler(feed, broker, ck, clock, seed=seed, batch=2)
                applier = IngestApplier(broker, feed, store, ck)
            applier.drain()
            clock.advance(30.0)
        pooler.checkpoint.close()
        return (
            {acc: store.study_etag(acc) for acc in store.accessions()},
            catalog.accession_etags(),
        )


# ---------------------------------------------- hypothesis: resume equivalence
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - container without hypothesis
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    class TestResumeEquivalenceProperty:
        @settings(max_examples=15, deadline=None)
        @given(
            crashes=st.lists(st.booleans(), max_size=6),
            seed=st.integers(0, 50),
        )
        def test_any_crash_schedule_converges_to_the_same_state(
            self, tmp_path_factory, crashes, seed
        ):
            """Pooler crash/restart at ANY poll boundary must leave the final
            lake + catalog state identical to an uninterrupted run."""
            tmp = tmp_path_factory.mktemp("resume")
            base = TestPoolerHandoff._run_with_crashes(tmp, "b", [], seed=seed)
            crashed = TestPoolerHandoff._run_with_crashes(
                tmp, "c", crashes, seed=seed
            )
            assert base == crashed

else:  # the deterministic variant above still covers the core equivalence

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_any_crash_schedule_converges_to_the_same_state():
        pass


# ------------------------------------------------- store change-seq + catalog
class TestStoreChangeSurface:
    def test_puts_and_deletes_append_changes(self):
        store = StudyStore("lake")
        gen = StudyGenerator(2)
        store.put_study("A", gen.gen_study("A", n_images=1))
        store.put_study("B", gen.gen_study("B", n_images=1))
        assert store.delete_study("A") is True
        assert store.delete_study("A") is False  # already gone
        assert store.change_seq() == 3
        ops = [(c.op, c.accession) for c in store.changes()]
        assert ops == [("put", "A"), ("put", "B"), ("delete", "A")]
        assert store.changes(after=2)[0].op == "delete"
        assert store.changes(after=2)[0].etag is None
        assert not store.has_study("A") and store.has_study("B")

    def test_delete_study_tombstones_catalog_delta(self):
        store = StudyStore("lake")
        catalog = StudyCatalog()
        store.attach_catalog(catalog)
        gen = StudyGenerator(2)
        store.put_study("A", gen.gen_study("A", n_images=3))
        store.put_study("B", gen.gen_study("B", n_images=2))
        rows_before = catalog.stats.rows
        store.delete_study("A")
        assert catalog.stats.rows == rows_before  # no re-ingest of B
        assert catalog.stats.tombstoned == 3  # exactly A's rows
        assert catalog.stats.deletes == 1
        assert catalog.accessions() == ["B"]


# ------------------------------------------------- worker-side stale fencing
class _MutatingStore(StudyStore):
    """Mutates the target accession immediately after its bytes are read —
    the tightest possible source-mutation race against an in-flight worker."""

    def arm(self, accession, study):
        self._arm = (accession, study)

    def get_study(self, accession):
        study = super().get_study(accession)
        armed = getattr(self, "_arm", None)
        if armed and armed[0] == accession:
            self._arm = None
            self.put_study(accession, armed[1])
        return study


def _service_env(tmp_path, store, n_studies=2, seed=7):
    clock = SimClock()
    gen = StudyGenerator(seed)
    mrns = {}
    for i in range(n_studies):
        acc = f"ACC{i:04d}"
        s = gen.gen_study(acc, modality="CT", n_images=1)
        store.put_study(acc, s)
        mrns[acc] = s.mrn
    broker = Broker(clock, visibility_timeout=60.0)
    journal = Journal(tmp_path / "journal.jsonl")
    lake = ResultLake(max_bytes=1 << 30)
    pipeline = DeidPipeline(recompress=False, lake=lake)
    service = DeidService(
        broker, store, journal, result_lake=lake, pipeline=pipeline
    )
    service.register_study("IRB-9", TrustMode.POST_IRB)
    dest = StudyStore("researcher")
    workers = []

    def make_worker(wid, **kw):
        w = DeidWorker(wid, pipeline, store, dest, journal, **kw)
        workers.append(w)
        return w

    pool = WorkerPool(
        broker, Autoscaler(broker, AutoscalerConfig(), clock), make_worker
    )
    return clock, broker, journal, lake, service, dest, pool, workers, mrns, pipeline


class TestStaleByteFencing:
    def test_fence_nacks_raced_read_and_redelivery_recovers(self, tmp_path):
        store = _MutatingStore("lake", key=b"k")
        clock, broker, journal, lake, service, dest, pool, workers, mrns, _ = (
            _service_env(tmp_path, store)
        )
        new_version = StudyGenerator(99).gen_study(
            "ACC0000", modality="CT", n_images=1
        )
        new_version.mrn = mrns["ACC0000"]  # same patient, re-acquired bytes
        store.arm("ACC0000", new_version)
        service.submit_cohort("IRB-9", list(mrns), mrns)
        pool.drain()
        assert sum(w.fenced for w in workers) == 1
        # the redelivery re-read post-mutation bytes; what was journaled is
        # exactly the current source version (never the pre-mutation read)
        assert journal.etag_for("IRB-9/ACC0000") == store.study_etag("ACC0000")

    def test_negative_control_fence_off_delivers_stale_bytes(self, tmp_path):
        store = _MutatingStore("lake", key=b"k")
        clock, broker, journal, lake, service, dest, pool, workers, mrns, pipe = (
            _service_env(tmp_path, store)
        )
        pool.make_worker = lambda wid: DeidWorker(
            wid, pipe, store, dest, journal, fence_stale_reads=False
        )
        new_version = StudyGenerator(99).gen_study(
            "ACC0000", modality="CT", n_images=1
        )
        new_version.mrn = mrns["ACC0000"]
        store.arm("ACC0000", new_version)
        service.submit_cohort("IRB-9", list(mrns), mrns)
        pool.drain()
        # without the fence the pre-mutation output IS delivered: the journal
        # pins an etag the source no longer holds (what Freshness would flag)
        assert journal.etag_for("IRB-9/ACC0000") != store.study_etag("ACC0000")

    def test_deleted_while_queued_is_fenced_to_dead_letter(self, tmp_path):
        store = StudyStore("lake", key=b"k")
        clock, broker, journal, lake, service, dest, pool, workers, mrns, _ = (
            _service_env(tmp_path, store)
        )
        service.submit_cohort("IRB-9", list(mrns), mrns)
        store.delete_study("ACC0000")
        pool.drain()
        assert sum(w.fenced for w in workers) >= 1
        assert not journal.is_done("IRB-9/ACC0000")
        assert any(m.key == "IRB-9/ACC0000" for m in broker.dead_letter)
        assert journal.is_done("IRB-9/ACC0001")  # the rest completed normally

    def test_supersession_evicts_stale_study_record(self, tmp_path):
        from repro.core.pipeline import build_request
        from repro.lake.fingerprint import request_salt, study_key

        store = StudyStore("lake", key=b"k")
        clock, broker, journal, lake, service, dest, pool, workers, mrns, pipe = (
            _service_env(tmp_path, store)
        )
        service.submit_cohort("IRB-9", list(mrns), mrns)
        pool.drain()
        assert journal.supersessions == 0
        old_etag = store.study_etag("ACC0000")
        request = build_request(
            service._studies["IRB-9"], "ACC0000", mrns["ACC0000"]
        )
        digest = pipe.ruleset_fingerprint().digest
        old_key = study_key("ACC0000", old_etag, digest, request_salt(request))
        assert lake.contains(old_key)  # warm study record from the first pass
        # re-acquisition: same accession, new bytes
        new_version = StudyGenerator(99).gen_study(
            "ACC0000", modality="CT", n_images=1
        )
        new_version.mrn = mrns["ACC0000"]
        store.put_study("ACC0000", new_version)
        ticket = service.submit_cohort("IRB-9", list(mrns), mrns)
        # only the mutated accession went cold; the other is still warm
        assert service.planner.stats.stale_refreshes == 1
        assert "ACC0000" in ticket.cold or "ACC0000" in ticket.pending
        pool.drain()
        assert journal.supersessions == 1
        assert journal.etag_for("IRB-9/ACC0000") == store.study_etag("ACC0000")
        assert sum(w.evicted_stale for w in workers) == 1
        # the pre-mutation study record is no longer materializable, and the
        # fresh one (new etag's key) took its place
        assert not lake.contains(old_key)
        new_key = study_key(
            "ACC0000", store.study_etag("ACC0000"), digest, request_salt(request)
        )
        assert lake.contains(new_key)
