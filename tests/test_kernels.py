"""Per-kernel allclose tests: Pallas (interpret mode) vs pure-jnp oracle vs
host reference, swept over shapes and dtypes."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.scrub import numpy_blank
from repro.dicom import codec
from repro.kernels.jls.ops import encode_batch, jls_residuals
from repro.kernels.jls.ref import residuals_ref
from repro.kernels.phi_detect.ops import edge_density, audit_image, suspicious_tiles
from repro.kernels.phi_detect.ref import edge_density_ref
from repro.kernels.scrub.ops import _SUBLANE, blank_fn, default_block, pack_rects, scrub_images
from repro.kernels.scrub.ref import scrub_ref

SHAPES = [(1, 32, 128), (2, 100, 170), (3, 256, 256), (1, 97, 513)]
DTYPES = [np.uint8, np.uint16, np.float32]


class TestScrubKernel:
    @pytest.mark.parametrize("shape", SHAPES)
    @pytest.mark.parametrize("dtype", DTYPES)
    def test_matches_ref_and_numpy(self, rng, shape, dtype):
        imgs = (rng.random(shape) * 200).astype(dtype)
        N, H, W = shape
        rl = []
        for i in range(N):
            rl.append(
                [
                    (0, 0, W, max(1, H // 8)),
                    (int(rng.integers(W)), int(rng.integers(H)), 40, 25),
                ][: 1 + i % 2]
            )
        rects = pack_rects(rl, R=3)
        out_k = np.asarray(scrub_images(jnp.asarray(imgs), rects))
        out_r = np.asarray(scrub_ref(jnp.asarray(imgs), jnp.asarray(rects)))
        out_n = np.stack([numpy_blank(imgs[i], rl[i]) for i in range(N)])
        np.testing.assert_array_equal(out_k, out_r)
        np.testing.assert_array_equal(out_k, out_n)

    def test_padding_rects_are_noops(self, rng):
        imgs = (rng.random((2, 64, 128)) * 200).astype(np.uint16)
        rects = np.zeros((2, 4, 4), np.int32)  # all padding
        out = np.asarray(scrub_images(jnp.asarray(imgs), rects))
        np.testing.assert_array_equal(out, imgs)

    def test_rect_clipping_at_borders(self, rng):
        imgs = (rng.random((1, 50, 140)) * 200).astype(np.uint8)
        rects = pack_rects([[(130, 45, 99, 99)]])  # overhangs both edges
        out = np.asarray(scrub_images(jnp.asarray(imgs), rects))
        assert (out[0, 45:, 130:] == 0).all()
        assert (out[0, :45, :130] == imgs[0, :45, :130]).all()

    def test_rect_entirely_off_frame_is_noop(self, rng):
        # regression: numpy_blank's slice end went negative and wrapped,
        # blanking nearly the whole frame for rects above/left of the image
        img = (rng.random((40, 60)) * 200).astype(np.uint8)
        rl = [(10, -8, 20, 4), (-30, 10, 25, 99), (10, 10, 5, 0)]
        np.testing.assert_array_equal(numpy_blank(img, rl), img)
        out = np.asarray(scrub_images(img[None], pack_rects([rl])))
        np.testing.assert_array_equal(out[0], img)

    def test_blank_fn_adapter(self, rng):
        img = (rng.random((70, 90)) * 4000).astype(np.uint16)
        rl = [(5, 5, 30, 20)]
        np.testing.assert_array_equal(blank_fn(img, rl), numpy_blank(img, rl))

    @pytest.mark.parametrize("block", [(32, 128), (64, 256), (256, 512)])
    def test_block_shape_sweep(self, rng, block):
        imgs = (rng.random((2, 300, 600)) * 200).astype(np.uint16)
        rl = [[(10, 10, 500, 100)], [(0, 250, 600, 50)]]
        rects = pack_rects(rl)
        out_k = np.asarray(scrub_images(jnp.asarray(imgs), rects, block=block))
        out_n = np.stack([numpy_blank(imgs[i], rl[i]) for i in range(2)])
        np.testing.assert_array_equal(out_k, out_n)


class TestPhiDetectKernel:
    @pytest.mark.parametrize("shape", [(1, 64, 128), (2, 96, 256)])
    @pytest.mark.parametrize("dtype", [np.uint8, np.uint16])
    def test_matches_ref(self, rng, shape, dtype):
        imgs = (rng.random(shape) * (255 if dtype == np.uint8 else 4095)).astype(dtype)
        thresh = (255.0 if dtype == np.uint8 else 4095.0) * 0.25
        den_k = np.asarray(edge_density(imgs, thresh=thresh, tile=(32, 128)))
        den_r = np.asarray(edge_density_ref(jnp.asarray(imgs), thresh, (32, 128)))
        np.testing.assert_allclose(den_k, den_r, atol=1e-6)

    def test_default_threshold_follows_dtype(self):
        from repro.kernels.phi_detect.ops import DEFAULT_THRESH_FRAC, full_scale

        # full-range uint16 (e.g. US captures) must not inherit the 12-bit max
        assert full_scale(np.uint16) == 65535.0
        assert full_scale(np.uint8) == 255.0
        assert full_scale(np.float32) == 1.0
        # BitsStored-style override for narrow ranges stored in wide dtypes
        assert full_scale(np.uint16, max_value=4095) == 4095.0
        # a 16-bit image with moderate (12-bit-scale) gradients is quiet under
        # the dtype default but flags once the true stored range is declared
        img = np.zeros((64, 128), np.uint16)
        img[:, ::2] = 4095  # max-contrast strokes at 12-bit scale
        assert not suspicious_tiles(img[None], tile=(32, 128)).any()
        assert suspicious_tiles(img[None], tile=(32, 128), max_value=4095).all()

    def test_audit_fails_closed_without_bitsstored(self):
        """A dataset missing BitsStored must not be audited at the dtype max
        (which no 12-bit gradient can reach): the ceiling is estimated from
        the observed sample range instead."""
        from repro.dicom.dataset import DicomDataset
        from repro.kernels.phi_detect.ops import audit_dataset, stored_max_value

        img = np.zeros((64, 128), np.uint16)
        img[:, ::2] = 4095  # burned-in text at 12-bit scale
        ds = DicomDataset(pixels=img)
        assert stored_max_value(ds) == 4095.0  # estimated, not 65535
        assert audit_dataset(ds)
        ds["BitsStored"] = 12  # declared depth takes precedence when present
        assert stored_max_value(ds) == 4095.0
        assert audit_dataset(ds)

    def test_detects_burned_in_text(self, gen):
        study = gen.gen_study("PHI-1", modality="US", n_images=1)
        img = study.datasets[0].pixels
        assert audit_image(img), "synthetic burn-in must be flagged"
        rects = study.phi_rects[study.datasets[0]["SOPInstanceUID"]]
        assert not audit_image(numpy_blank(img, rects)), "scrubbed image must be clean"

    def test_flat_image_not_flagged(self):
        img = np.full((256, 256), 100, np.uint8)
        assert not suspicious_tiles(img[None]).any()


class TestPackRects:
    def test_grows_to_longest_list(self):
        out = pack_rects([[(1, 2, 3, 4)], [(5, 6, 7, 8), (9, 10, 11, 12), (13, 14, 15, 16)]])
        assert out.shape == (2, 3, 4)
        np.testing.assert_array_equal(out[1, 2], [13, 14, 15, 16])

    def test_refuses_to_truncate(self):
        # regression: used to silently drop rects beyond R, shipping PHI
        with pytest.raises(ValueError, match="refusing to truncate"):
            pack_rects([[(0, 0, 1, 1)] * 5], R=3)

    def test_explicit_r_pads(self):
        out = pack_rects([[(1, 1, 2, 2)]], R=4)
        assert out.shape == (1, 4, 4)
        assert (out[0, 1:] == 0).all()

    def test_empty_inputs(self):
        assert pack_rects([]).shape == (0, 1, 4)
        assert pack_rects([[], []]).shape == (2, 1, 4)


class TestDefaultBlock:
    @pytest.mark.parametrize("shape", [(300, 300), (512, 1), (1024, 768), (1, 1), (97, 513), (2500, 2048)])
    @pytest.mark.parametrize("dtype", [np.uint8, np.uint16, np.float32])
    def test_alignment_and_bounded_padding(self, shape, dtype):
        H, W = shape
        sub = _SUBLANE[np.dtype(dtype).itemsize]
        bh, bw = default_block(dtype, H, W)
        assert bw % 128 == 0 and bh % sub == 0
        assert 128 <= bw <= 512 and sub <= bh <= 256
        # pad-to-tile-multiple never adds a full tile in either dimension
        Hp = (H + bh - 1) // bh * bh
        Wp = (W + bw - 1) // bw * bw
        assert Hp - H < bh and Wp - W < bw

    @pytest.mark.parametrize("shape", [(300, 300), (512, 1), (1024, 768)])
    def test_scrub_correct_on_odd_shapes(self, rng, shape):
        H, W = shape
        imgs = (rng.random((1, H, W)) * 4000).astype(np.uint16)
        rl = [[(W // 3, H // 3, W // 2, H // 2), (0, 0, W, 5)]]
        out = np.asarray(scrub_images(imgs, pack_rects(rl)))
        np.testing.assert_array_equal(out, numpy_blank(imgs[0], rl[0])[None])


class TestJlsKernel:
    @pytest.mark.parametrize("sv", list(range(1, 8)))
    @pytest.mark.parametrize("dtype,bits", [(np.uint8, 8), (np.uint16, 16)])
    def test_matches_ref_and_codec(self, rng, sv, dtype, bits):
        img = (rng.random((2, 70, 90)) * ((1 << bits) - 1)).astype(dtype)
        rk = np.asarray(jls_residuals(img, sv=sv))
        rr = np.asarray(residuals_ref(jnp.asarray(img), sv, bits))
        rc = np.stack([codec.residuals(img[i], sv) for i in range(2)])
        np.testing.assert_array_equal(rk, rr)
        np.testing.assert_array_equal(rk, rc)

    @pytest.mark.parametrize("bh", [8, 32, 64])
    def test_block_height_sweep(self, rng, bh):
        img = (rng.random((1, 130, 64)) * 4095).astype(np.uint16)
        rk = np.asarray(jls_residuals(img, sv=4, bh=bh))
        rc = codec.residuals(img[0], 4)[None]
        np.testing.assert_array_equal(rk, rc)

    def test_encode_batch_byte_identical(self, rng):
        img = (rng.random((2, 48, 64)) * 4095).astype(np.uint16)
        bufs = encode_batch(img, sv=1)
        for i in range(2):
            assert bufs[i] == codec.encode(img[i], 1)
            np.testing.assert_array_equal(codec.decode(bufs[i]), img[i])

    def test_pack_header_is_the_shared_layout(self, rng):
        # encode == pack_header + rice payload, for host and kernel paths alike
        img = (rng.random((20, 32)) * 255).astype(np.uint8)
        res = codec.residuals(img, 2)
        payload, k = codec.rice_encode(res)
        assert codec.encode(img, 2) == codec.pack_header(20, 32, 8, 2, k, len(payload)) + payload
