"""Per-kernel allclose tests: Pallas (interpret mode) vs pure-jnp oracle vs
host reference, swept over shapes and dtypes."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.scrub import numpy_blank
from repro.dicom import codec
from repro.kernels.jls.ops import encode_batch, jls_residuals
from repro.kernels.jls.ref import residuals_ref
from repro.kernels.phi_detect.ops import edge_density, audit_image, suspicious_tiles
from repro.kernels.phi_detect.ref import edge_density_ref
from repro.kernels.scrub.ops import blank_fn, pack_rects, scrub_images
from repro.kernels.scrub.ref import scrub_ref

SHAPES = [(1, 32, 128), (2, 100, 170), (3, 256, 256), (1, 97, 513)]
DTYPES = [np.uint8, np.uint16, np.float32]


class TestScrubKernel:
    @pytest.mark.parametrize("shape", SHAPES)
    @pytest.mark.parametrize("dtype", DTYPES)
    def test_matches_ref_and_numpy(self, rng, shape, dtype):
        imgs = (rng.random(shape) * 200).astype(dtype)
        N, H, W = shape
        rl = []
        for i in range(N):
            rl.append(
                [
                    (0, 0, W, max(1, H // 8)),
                    (int(rng.integers(W)), int(rng.integers(H)), 40, 25),
                ][: 1 + i % 2]
            )
        rects = pack_rects(rl, R=3)
        out_k = np.asarray(scrub_images(jnp.asarray(imgs), rects))
        out_r = np.asarray(scrub_ref(jnp.asarray(imgs), jnp.asarray(rects)))
        out_n = np.stack([numpy_blank(imgs[i], rl[i]) for i in range(N)])
        np.testing.assert_array_equal(out_k, out_r)
        np.testing.assert_array_equal(out_k, out_n)

    def test_padding_rects_are_noops(self, rng):
        imgs = (rng.random((2, 64, 128)) * 200).astype(np.uint16)
        rects = np.zeros((2, 4, 4), np.int32)  # all padding
        out = np.asarray(scrub_images(jnp.asarray(imgs), rects))
        np.testing.assert_array_equal(out, imgs)

    def test_rect_clipping_at_borders(self, rng):
        imgs = (rng.random((1, 50, 140)) * 200).astype(np.uint8)
        rects = pack_rects([[(130, 45, 99, 99)]])  # overhangs both edges
        out = np.asarray(scrub_images(jnp.asarray(imgs), rects))
        assert (out[0, 45:, 130:] == 0).all()
        assert (out[0, :45, :130] == imgs[0, :45, :130]).all()

    def test_blank_fn_adapter(self, rng):
        img = (rng.random((70, 90)) * 4000).astype(np.uint16)
        rl = [(5, 5, 30, 20)]
        np.testing.assert_array_equal(blank_fn(img, rl), numpy_blank(img, rl))

    @pytest.mark.parametrize("block", [(32, 128), (64, 256), (256, 512)])
    def test_block_shape_sweep(self, rng, block):
        imgs = (rng.random((2, 300, 600)) * 200).astype(np.uint16)
        rl = [[(10, 10, 500, 100)], [(0, 250, 600, 50)]]
        rects = pack_rects(rl)
        out_k = np.asarray(scrub_images(jnp.asarray(imgs), rects, block=block))
        out_n = np.stack([numpy_blank(imgs[i], rl[i]) for i in range(2)])
        np.testing.assert_array_equal(out_k, out_n)


class TestPhiDetectKernel:
    @pytest.mark.parametrize("shape", [(1, 64, 128), (2, 96, 256)])
    @pytest.mark.parametrize("dtype", [np.uint8, np.uint16])
    def test_matches_ref(self, rng, shape, dtype):
        imgs = (rng.random(shape) * (255 if dtype == np.uint8 else 4095)).astype(dtype)
        den_k = np.asarray(edge_density(imgs, tile=(32, 128)))
        thresh = (255.0 if dtype == np.uint8 else 4095.0) * 0.25
        den_r = np.asarray(edge_density_ref(jnp.asarray(imgs), thresh, (32, 128)))
        np.testing.assert_allclose(den_k, den_r, atol=1e-6)

    def test_detects_burned_in_text(self, gen):
        study = gen.gen_study("PHI-1", modality="US", n_images=1)
        img = study.datasets[0].pixels
        assert audit_image(img), "synthetic burn-in must be flagged"
        rects = study.phi_rects[study.datasets[0]["SOPInstanceUID"]]
        assert not audit_image(numpy_blank(img, rects)), "scrubbed image must be clean"

    def test_flat_image_not_flagged(self):
        img = np.full((256, 256), 100, np.uint8)
        assert not suspicious_tiles(img[None]).any()


class TestJlsKernel:
    @pytest.mark.parametrize("sv", list(range(1, 8)))
    @pytest.mark.parametrize("dtype,bits", [(np.uint8, 8), (np.uint16, 16)])
    def test_matches_ref_and_codec(self, rng, sv, dtype, bits):
        img = (rng.random((2, 70, 90)) * ((1 << bits) - 1)).astype(dtype)
        rk = np.asarray(jls_residuals(img, sv=sv))
        rr = np.asarray(residuals_ref(jnp.asarray(img), sv, bits))
        rc = np.stack([codec.residuals(img[i], sv) for i in range(2)])
        np.testing.assert_array_equal(rk, rr)
        np.testing.assert_array_equal(rk, rc)

    @pytest.mark.parametrize("bh", [8, 32, 64])
    def test_block_height_sweep(self, rng, bh):
        img = (rng.random((1, 130, 64)) * 4095).astype(np.uint16)
        rk = np.asarray(jls_residuals(img, sv=4, bh=bh))
        rc = codec.residuals(img[0], 4)[None]
        np.testing.assert_array_equal(rk, rc)

    def test_encode_batch_byte_identical(self, rng):
        img = (rng.random((2, 48, 64)) * 4095).astype(np.uint16)
        bufs = encode_batch(img, sv=1)
        for i in range(2):
            assert bufs[i] == codec.encode(img[i], 1)
            np.testing.assert_array_equal(codec.decode(bufs[i]), img[i])
