"""De-id result lake tests: cache key derivation, LRU store, cache-aware
pipeline (warm == cold, byte-identical), cohort planner partitioning,
single-flight coalescing, and ruleset-fingerprint invalidation."""
import pickle

import pytest

from repro.core import DeidPipeline, PseudonymService, TrustMode, build_request
from repro.core.scripts import DEFAULT_SCRUB_SCRIPT
from repro.dicom.generator import StudyGenerator
from repro.lake import (
    CohortPlanner,
    ResultLake,
    RulesetFingerprint,
    cache_key,
    geometry_digest,
    instance_digest,
    request_salt,
)
from repro.queueing import (
    Autoscaler,
    AutoscalerConfig,
    Broker,
    DeidWorker,
    FailureInjector,
    Journal,
    WorkerPool,
)
from repro.queueing.server import DeidService
from repro.storage.object_store import StudyStore
from repro.utils.timing import SimClock

try:
    from hypothesis import HealthCheck, given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False


def _ds_bytes(ds):
    """Canonical content bytes of a dataset (byte-identity comparisons)."""
    pix = (
        None
        if ds.pixels is None
        else (ds.pixels.dtype.name, ds.pixels.shape, ds.pixels.tobytes())
    )
    return pickle.dumps(
        ({k: str(v) for k, v in ds.elements.items()}, dict(ds.private), pix, ds.encapsulated)
    )


def _pseudo(study_id="IRB-LAKE"):
    return PseudonymService(study_id, TrustMode.POST_IRB, key=b"k" * 32)


# --------------------------------------------------------------------------- keys
class TestFingerprint:
    def test_deterministic(self):
        p1, p2 = DeidPipeline(recompress=False), DeidPipeline(recompress=False)
        assert p1.ruleset_fingerprint().digest == p2.ruleset_fingerprint().digest

    def test_rule_edit_changes_digest(self):
        base = DeidPipeline(recompress=False)
        edited = DeidPipeline(
            recompress=False,
            scrub_script=DEFAULT_SCRUB_SCRIPT + "\nscrub CT Acme NewModel 64x64 (0,0,64,8)\n",
        )
        assert base.ruleset_fingerprint().digest != edited.ruleset_fingerprint().digest

    def test_geometry_digest_tracks_rects(self):
        class _Reg:
            def __init__(self, shift):
                self.shift = shift

            def all_us_variants(self):
                return []

            def scrub_rects(self, key):
                return [(0, 0, 100 + self.shift, 20)]

        assert geometry_digest(_Reg(0)) == geometry_digest(_Reg(0))
        assert geometry_digest(_Reg(0)) != geometry_digest(_Reg(1))

    def test_salt_scopes_projects(self, gen):
        s = gen.gen_study("SALT1", modality="CT", n_images=1)
        r1 = build_request(_pseudo("IRB-A"), s.accession, s.mrn)
        r2 = build_request(_pseudo("IRB-B"), s.accession, s.mrn)
        assert request_salt(r1) != request_salt(r2)
        assert request_salt(r1) == request_salt(
            build_request(_pseudo("IRB-A"), s.accession, s.mrn)
        )
        d = instance_digest(s.datasets[0])
        fp = DeidPipeline(recompress=False).ruleset_fingerprint().digest
        assert cache_key(d, fp, request_salt(r1)) != cache_key(d, fp, request_salt(r2))

    def test_callable_identity_tracks_behavior(self):
        import functools

        from repro.core import numpy_blank
        from repro.lake.fingerprint import callable_identity

        a = lambda px, rects: px                 # noqa: E731
        b = lambda px, rects: None               # noqa: E731
        assert callable_identity(a) != callable_identity(b)  # same name, diff body
        assert callable_identity(numpy_blank) == callable_identity(numpy_blank)
        # partial identity must be address-free (stable across processes)
        p = callable_identity(functools.partial(numpy_blank))
        assert "0x" not in p and "numpy_blank" in p

    def test_instance_digest_tracks_content(self, gen):
        s = gen.gen_study("DIG1", modality="CT", n_images=1)
        ds = s.datasets[0]
        d0 = instance_digest(ds)
        assert d0 == instance_digest(ds.copy())
        edited = ds.copy()
        edited.pixels[0, 0] ^= 1
        assert instance_digest(edited) != d0
        relabeled = ds.copy()
        relabeled["PatientName"] = "OTHER^NAME"
        assert instance_digest(relabeled) != d0


# -------------------------------------------------------------------------- store
class TestResultLake:
    def test_roundtrip_and_metrics(self):
        lake = ResultLake(max_bytes=1000)
        assert lake.get("a") is None
        assert lake.stats.misses == 1
        lake.put("a", b"x" * 10)
        assert lake.get("a") == b"x" * 10
        assert lake.stats.hits == 1 and lake.stats.puts == 1
        assert lake.stats.bytes_in == 10 and lake.stats.bytes_out == 10
        assert lake.stats.hit_rate() == 0.5

    def test_lru_eviction_order(self):
        lake = ResultLake(max_bytes=30)
        lake.put("a", b"x" * 10)
        lake.put("b", b"y" * 10)
        lake.put("c", b"z" * 10)
        assert lake.get("a") is not None  # refresh a: b is now least recent
        lake.put("d", b"w" * 10)
        assert not lake.contains("b") and lake.contains("a")
        assert lake.stats.evictions == 1 and lake.stats.evicted_bytes == 10
        assert lake.stored_bytes() <= 30

    def test_overwrite_does_not_leak_bytes(self):
        lake = ResultLake(max_bytes=100)
        lake.put("a", b"x" * 40)
        lake.put("a", b"y" * 20)
        assert lake.stored_bytes() == 20

    def test_oversize_rejected(self):
        lake = ResultLake(max_bytes=10)
        assert not lake.put("big", b"x" * 11)
        assert lake.stats.oversize_rejects == 1 and len(lake) == 0


# ----------------------------------------------------------------- pipeline cache
class TestPipelineCache:
    def _study(self, acc="PC01", modality="CT", n_images=4):
        return StudyGenerator(33).gen_study(acc, modality=modality, n_images=n_images)

    def test_warm_replay_is_byte_identical_and_dispatch_free(self):
        study = self._study()
        req = build_request(_pseudo(), study.accession, study.mrn)
        cold_pipe = DeidPipeline(recompress=True)  # oracle: no lake at all
        cold_out, cold_manifest = cold_pipe.process_study(study, req)

        lake = ResultLake()
        pipe = DeidPipeline(recompress=True, lake=lake)
        first = pipe.run_study(study, req)
        assert first.cache_misses == len(study.datasets) and first.cache_hits == 0

        d0 = pipe.executor.stats.dispatches
        warm = pipe.run_study(study, req)
        assert pipe.executor.stats.dispatches == d0  # zero kernel dispatches
        assert warm.cache_hits == len(study.datasets) and warm.cache_misses == 0

        assert warm.manifest.to_json() == cold_manifest.to_json()
        assert len(warm.delivered) == len(cold_out)
        for w, c in zip(warm.delivered, cold_out):
            assert _ds_bytes(w) == _ds_bytes(c)

    def test_ruleset_bump_forces_recompute(self):
        study = self._study("PC02")
        req = build_request(_pseudo(), study.accession, study.mrn)
        lake = ResultLake()
        pipe = DeidPipeline(recompress=False, lake=lake)
        pipe.run_study(study, req)
        # same ruleset, fresh pipeline instance: fully warm
        again = DeidPipeline(recompress=False, lake=lake).run_study(study, req)
        assert again.cache_hits == len(study.datasets)
        # edited scrub rule = new fingerprint: nothing may be reused
        edited = DeidPipeline(
            recompress=False,
            lake=lake,
            scrub_script=DEFAULT_SCRUB_SCRIPT + "\nscrub CT Acme NewModel 64x64 (0,0,64,8)\n",
        )
        res = edited.run_study(study, req)
        assert res.cache_hits == 0 and res.cache_misses == len(study.datasets)

    def test_geometry_bump_forces_recompute(self):
        study = self._study("PC03")
        req = build_request(_pseudo(), study.accession, study.mrn)
        lake = ResultLake()
        pipe = DeidPipeline(recompress=False, lake=lake)
        pipe.run_study(study, req)
        bumped = DeidPipeline(recompress=False, lake=lake)
        fp = bumped.ruleset_fingerprint()
        # simulate a device-registry geometry change (new rect layout digest)
        bumped._fingerprint = RulesetFingerprint(
            fp.filter_sha, fp.anonymizer_sha, fp.scrubber_sha, "deadbeef"
        )
        res = bumped.run_study(study, req)
        assert res.cache_hits == 0 and res.cache_misses == len(study.datasets)

    def test_recompress_config_does_not_share_keys(self):
        """Pipelines differing in output-shaping config (recompress/sv) must
        not serve each other's cached bytes."""
        study = self._study("PC05")
        req = build_request(_pseudo(), study.accession, study.mrn)
        lake = ResultLake()
        DeidPipeline(recompress=False, lake=lake).run_study(study, req)
        res = DeidPipeline(recompress=True, lake=lake).run_study(study, req)
        assert res.cache_hits == 0 and res.cache_misses == len(study.datasets)
        for entry in res.manifest.entries:  # recompression actually happened
            assert entry.recompressed

    def test_partial_study_update_recomputes_only_new_slices(self):
        study = self._study("PC04", n_images=5)
        req = build_request(_pseudo(), study.accession, study.mrn)
        lake = ResultLake()
        pipe = DeidPipeline(recompress=False, lake=lake)
        pipe.run_study(study, req)
        study.datasets[2].pixels[0, 0] ^= 1  # one re-acquired slice
        res = pipe.run_study(study, req)
        assert res.cache_hits == 4 and res.cache_misses == 1


def _check_warm_replay_matches_cold(modality, n_images, seed):
    """Satellite property: warm-cache replay is byte-identical to cold."""
    study = StudyGenerator(seed).gen_study(
        f"HYP-{seed}", modality=modality, n_images=n_images
    )
    req = build_request(_pseudo(), study.accession, study.mrn)
    cold_out, cold_manifest = DeidPipeline(recompress=False).process_study(study, req)
    lake = ResultLake()
    pipe = DeidPipeline(recompress=False, lake=lake)
    pipe.run_study(study, req)
    warm = pipe.run_study(study, req)
    assert warm.cache_misses == 0
    assert warm.manifest.to_json() == cold_manifest.to_json()
    assert [_ds_bytes(d) for d in warm.delivered] == [_ds_bytes(d) for d in cold_out]


if HAVE_HYPOTHESIS:

    class TestCacheProperties:
        @settings(
            max_examples=8, deadline=None, suppress_health_check=[HealthCheck.too_slow]
        )
        @given(
            modality=st.sampled_from(["CT", "US", "DX"]),
            n_images=st.integers(1, 4),
            seed=st.integers(0, 2**16),
        )
        def test_warm_cohort_replay_matches_cold(self, modality, n_images, seed):
            _check_warm_replay_matches_cold(modality, n_images, seed)

else:  # seeded sweep fallback (mirrors test_properties.py's skip philosophy,
       # but keeps the byte-identity property exercised without hypothesis)

    class TestCacheProperties:
        @pytest.mark.parametrize(
            "modality,n_images,seed",
            [("CT", 2, 0), ("US", 3, 1), ("DX", 1, 2), ("US", 1, 3), ("CT", 4, 4)],
        )
        def test_warm_cohort_replay_matches_cold(self, modality, n_images, seed):
            _check_warm_replay_matches_cold(modality, n_images, seed)


# ------------------------------------------------------------------ cohort planner
def _lake_env(tmp_path, journal_name, result_lake, source=None, mrns=None, n_studies=3):
    """One service+pool stack. Pass the same (source, result_lake) to model a
    second deployment (fresh broker/journal) over the same durable stores."""
    clock = SimClock()
    if source is None:
        gen = StudyGenerator(21)
        source, mrns = StudyStore("lake", key=b"lake-key"), {}
        for i in range(n_studies):
            acc = f"C{i:03d}"
            s = gen.gen_study(acc, modality="CT", n_images=3)
            source.put_study(acc, s)
            mrns[acc] = s.mrn
    broker = Broker(clock, visibility_timeout=30.0)
    journal = Journal(tmp_path / journal_name)
    pipeline = DeidPipeline(recompress=False, lake=result_lake)
    service = DeidService(broker, source, journal, result_lake=result_lake, pipeline=pipeline)
    service.register_study("IRB-LAKE", TrustMode.POST_IRB)
    dest = StudyStore("researcher")
    pool = WorkerPool(
        broker,
        Autoscaler(broker, AutoscalerConfig(), clock),
        lambda wid: DeidWorker(wid, pipeline, source, dest, journal),
    )
    return source, mrns, broker, journal, pipeline, service, dest, pool


class TestCohortPlanner:
    def test_cold_then_fully_warm_repeat(self, tmp_path):
        result_lake = ResultLake()
        source, mrns, broker, journal, pipeline, service, dest, pool = _lake_env(
            tmp_path, "j1.jsonl", result_lake
        )
        ticket = service.submit_cohort("IRB-LAKE", list(mrns), mrns)
        assert sorted(ticket.cold) == sorted(mrns) and not ticket.hits
        pool.drain()
        assert service.planner.resolve() == [f"IRB-LAKE/{a}" for a in ticket.cold]
        assert ticket.done()
        assert set(ticket.manifests) == set(mrns) and set(ticket.outputs) == set(mrns)
        first_outputs = {
            a: [_ds_bytes(d) for d in ticket.outputs[a]] for a in mrns
        }
        first_manifests = {a: ticket.manifests[a].to_json() for a in mrns}

        # second deployment: fresh broker/journal/planner, same lake + source.
        # 100% cache hit => zero broker publishes, zero kernel dispatches.
        _, _, broker2, journal2, pipeline2, service2, dest2, pool2 = _lake_env(
            tmp_path, "j2.jsonl", result_lake, source=source, mrns=mrns
        )
        d0 = pipeline2.executor.stats.dispatches
        warm_ticket = service2.submit_cohort("IRB-LAKE", list(mrns), mrns)
        assert sorted(warm_ticket.hits) == sorted(mrns)
        assert not warm_ticket.cold and not warm_ticket.coalesced
        assert warm_ticket.done()
        assert broker2.total_published == 0            # zero broker publishes
        assert pipeline2.executor.stats.dispatches == d0  # zero kernel dispatches
        assert service2.planner.stats.lake_hits == len(mrns)
        for a in mrns:  # byte-identical to the cold path
            assert [_ds_bytes(d) for d in warm_ticket.outputs[a]] == first_outputs[a]
            assert warm_ticket.manifests[a].to_json() == first_manifests[a]

    def test_single_flight_coalescing(self, tmp_path):
        result_lake = ResultLake()
        source, mrns, broker, journal, pipeline, service, dest, pool = _lake_env(
            tmp_path, "j1.jsonl", result_lake
        )
        acc = list(mrns)[0]
        t1 = service.submit_cohort("IRB-LAKE", [acc], mrns)
        published = broker.total_published
        assert t1.cold == [acc] and published == 1
        # concurrent overlapping cohorts: subscribe, don't republish
        t2 = service.submit_cohort("IRB-LAKE", [acc], mrns)
        t3 = service.submit_cohort("IRB-LAKE", [acc], mrns)
        assert t2.coalesced == [acc] and t3.coalesced == [acc]
        assert broker.total_published == published  # single-flight held
        assert service.planner.stats.coalesced == 2
        pool.drain()
        service.planner.resolve()
        assert t1.done() and t2.done() and t3.done()
        assert (
            t1.manifests[acc].to_json()
            == t2.manifests[acc].to_json()
            == t3.manifests[acc].to_json()
        )
        # the study was processed exactly once (journal + single-flight)
        assert journal.completed_keys() == {f"IRB-LAKE/{acc}"}

    def test_plain_submit_composes_with_single_flight(self, tmp_path):
        """Regression: DeidService.submit must neither republish an accession
        a cohort already has in flight, nor publish invisibly to cohorts."""
        result_lake = ResultLake()
        source, mrns, broker, journal, pipeline, service, dest, pool = _lake_env(
            tmp_path, "j1.jsonl", result_lake
        )
        accs = list(mrns)
        t1 = service.submit_cohort("IRB-LAKE", [accs[0]], mrns)
        service.submit("IRB-LAKE", [accs[0]], mrns)  # overlapping plain submit
        assert broker.total_published == 1  # no duplicate publish

        service.submit("IRB-LAKE", [accs[1]], mrns)  # plain submit first
        assert broker.total_published == 2
        t2 = service.submit_cohort("IRB-LAKE", [accs[1]], mrns)
        assert t2.coalesced == [accs[1]]  # cohort coalesces onto it
        assert broker.total_published == 2

        pool.drain()
        service.planner.resolve()
        assert t1.done() and t2.done()
        # each accession was computed exactly once
        assert journal.completed_keys() == {f"IRB-LAKE/{a}" for a in accs[:2]}

        # after completion, a new cohort is served warm — in-flight entries
        # from plain submits resolve at admission, they don't linger
        t3 = service.submit_cohort("IRB-LAKE", accs[:2], mrns)
        assert sorted(t3.hits) == sorted(accs[:2]) and not t3.coalesced

    def test_eviction_demotes_to_cold(self, tmp_path):
        result_lake = ResultLake()
        source, mrns, broker, journal, pipeline, service, dest, pool = _lake_env(
            tmp_path, "j1.jsonl", result_lake
        )
        acc = list(mrns)[0]
        service.submit_cohort("IRB-LAKE", [acc], mrns)
        pool.drain()
        service.planner.resolve()
        # evict one instance blob behind the study record's back
        from repro.lake.records import decode_study_record

        skey = None
        for k in result_lake.keys():
            try:
                keys = decode_study_record(result_lake.backend.get_bytes(k))
                skey = k
                break
            except Exception:
                continue
        assert skey is not None
        result_lake.delete(keys[0])

        # fresh deployment so the journal cannot answer: must go cold again
        _, _, broker2, journal2, pipeline2, service2, dest2, pool2 = _lake_env(
            tmp_path, "j2.jsonl", result_lake, source=source, mrns=mrns
        )
        t = service2.submit_cohort("IRB-LAKE", [acc], mrns)
        assert t.cold == [acc]
        assert service2.planner.stats.demoted == 1
        assert not result_lake.contains(skey)  # stale study record dropped

    def test_journal_hit_when_lake_evicted(self, tmp_path):
        result_lake = ResultLake()
        source, mrns, broker, journal, pipeline, service, dest, pool = _lake_env(
            tmp_path, "j1.jsonl", result_lake
        )
        acc = list(mrns)[0]
        service.submit_cohort("IRB-LAKE", [acc], mrns)
        pool.drain()
        service.planner.resolve()
        for k in result_lake.keys():  # total eviction
            result_lake.delete(k)
        t = service.submit_cohort("IRB-LAKE", [acc], mrns)
        # already completed: outputs sit in the researcher bucket; the
        # manifest replays from the journal, and nothing is republished
        assert t.hits == [acc] and acc in t.manifests and acc not in t.outputs
        assert service.planner.stats.journal_hits == 1
        assert broker.total_published == 1  # only the original cold publish

    def test_dead_lettered_work_fails_out_and_can_republish(self, tmp_path):
        """A poisoned accession must not wedge single-flight forever: its
        subscribers are failed out at resolve(), and a later cohort can
        republish once the fault clears."""
        result_lake = ResultLake()
        source, mrns, broker, journal, pipeline, service, dest, pool = _lake_env(
            tmp_path, "j1.jsonl", result_lake
        )
        broker.max_deliveries = 2
        acc = list(mrns)[0]
        pool.injector = FailureInjector(crash_rate=1.0)  # every delivery dies
        t1 = service.submit_cohort("IRB-LAKE", [acc], mrns)
        t2 = service.submit_cohort("IRB-LAKE", [acc], mrns)  # coalesces onto t1
        pool.drain()
        assert broker.stats().dead_lettered == 1
        service.planner.resolve()
        assert t1.done() and t2.done()
        assert acc in t1.failed and acc in t2.failed
        assert service.planner.stats.dead_lettered == 1
        # the in-flight registration is gone: recovery is a plain republish
        pool.injector = None
        t3 = service.submit_cohort("IRB-LAKE", [acc], mrns)
        assert t3.cold == [acc]
        # regression: polling resolve() while the republished message is still
        # in flight must NOT match the old DLQ entry and fail the live work
        service.planner.resolve()
        assert not t3.done() and acc not in t3.failed
        pool.drain()
        service.planner.resolve()
        assert t3.done() and acc in t3.manifests and not t3.failed

    def test_oversize_instances_do_not_write_doomed_study_records(self, tmp_path):
        """Regression: when instance records can't land in the lake (oversize
        reject), no study record may be written — otherwise every later
        cohort would demote/recompute/rewrite it forever."""
        result_lake = ResultLake(max_bytes=64)  # smaller than any record
        source, mrns, broker, journal, pipeline, service, dest, pool = _lake_env(
            tmp_path, "j1.jsonl", result_lake
        )
        acc = list(mrns)[0]
        service.submit_cohort("IRB-LAKE", [acc], mrns)
        pool.drain()
        service.planner.resolve()
        assert result_lake.stats.oversize_rejects > 0
        assert len(result_lake) == 0  # no study record pointing at nothing

        # a later deployment just goes cold — no demote churn
        _, _, broker2, journal2, pipeline2, service2, dest2, pool2 = _lake_env(
            tmp_path, "j2.jsonl", result_lake, source=source, mrns=mrns
        )
        t = service2.submit_cohort("IRB-LAKE", [acc], mrns)
        assert t.cold == [acc]
        assert service2.planner.stats.demoted == 0

    def test_worker_lake_counters(self, tmp_path):
        result_lake = ResultLake()
        source, mrns, broker, journal, pipeline, service, dest, pool = _lake_env(
            tmp_path, "j1.jsonl", result_lake
        )
        service.submit_cohort("IRB-LAKE", list(mrns), mrns)
        pool.drain()
        total = sum(w.lake_misses for w in pool._all_workers)
        assert total == 3 * len(mrns)  # every instance was a cold miss
        assert sum(w.lake_hits for w in pool._all_workers) == 0
