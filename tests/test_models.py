"""Model-zoo correctness: chunked kernels vs O(S^2)/sequential oracles,
decode-vs-forward consistency per family, and structural invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.model import ModelConfig
from repro.config.registry import get_arch, list_archs
from repro.models import build_model
from repro.models.attention import chunked_attention, reference_attention
from repro.models.moe import moe_apply, moe_apply_dense_eval, moe_specs
from repro.models.spec import param_count, tree_init
from repro.models import ssm as ssm_mod


def _rand(key, shape):
    return jax.random.normal(key, shape, jnp.float32) * 0.5


# ------------------------------------------------------------- attention
class TestChunkedAttention:
    @pytest.mark.parametrize("causal", [True, False])
    @pytest.mark.parametrize("H,KV", [(4, 4), (8, 2)])
    def test_matches_reference(self, causal, H, KV):
        key = jax.random.PRNGKey(0)
        B, S, hd = 2, 256, 16
        q = _rand(key, (B, S, H, hd))
        k = _rand(jax.random.fold_in(key, 1), (B, S, KV, hd))
        v = _rand(jax.random.fold_in(key, 2), (B, S, KV, hd))
        out = chunked_attention(q, k, v, causal=causal, chunk=64)
        ref = reference_attention(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)

    @pytest.mark.parametrize("window", [32, 64, 100])
    def test_sliding_window_matches_reference(self, window):
        key = jax.random.PRNGKey(3)
        B, S, H, hd = 1, 256, 2, 16
        q = _rand(key, (B, S, H, hd))
        k = _rand(jax.random.fold_in(key, 1), (B, S, H, hd))
        v = _rand(jax.random.fold_in(key, 2), (B, S, H, hd))
        out = chunked_attention(q, k, v, causal=True, window=window, chunk=64)
        ref = reference_attention(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)

    @pytest.mark.parametrize("chunk", [32, 128, 256])
    def test_chunk_size_invariance(self, chunk):
        key = jax.random.PRNGKey(4)
        B, S, H, hd = 1, 256, 2, 8
        q = _rand(key, (B, S, H, hd))
        k = _rand(jax.random.fold_in(key, 1), (B, S, H, hd))
        v = _rand(jax.random.fold_in(key, 2), (B, S, H, hd))
        a = chunked_attention(q, k, v, causal=True, chunk=chunk)
        b = chunked_attention(q, k, v, causal=True, chunk=S)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)

    def test_causality(self):
        """Perturbing future keys/values must not change past outputs."""
        key = jax.random.PRNGKey(5)
        B, S, H, hd = 1, 128, 2, 8
        q = _rand(key, (B, S, H, hd))
        k = _rand(jax.random.fold_in(key, 1), (B, S, H, hd))
        v = _rand(jax.random.fold_in(key, 2), (B, S, H, hd))
        out1 = chunked_attention(q, k, v, causal=True, chunk=32)
        k2 = k.at[:, 100:].set(99.0)
        v2 = v.at[:, 100:].set(-99.0)
        out2 = chunked_attention(q, k2, v2, causal=True, chunk=32)
        np.testing.assert_allclose(np.asarray(out1[:, :100]), np.asarray(out2[:, :100]), atol=1e-6)


# ------------------------------------------------------------------ ssm
def _mamba1_sequential(p, cfg, x_conv, h0):
    """Token-by-token oracle for the chunked selective scan."""
    B, S, di = x_conv.shape
    N, R = cfg.ssm_state, cfg.dt_rank
    proj = (x_conv @ p["x_proj"]).astype(jnp.float32)
    dt_r, Bm, Cm = proj[..., :R], proj[..., R : R + N], proj[..., R + N :]
    dt = jax.nn.softplus(dt_r @ p["dt_w"].astype(jnp.float32) + p["dt_b"])
    A = -jnp.exp(p["A_log"])
    xf = x_conv.astype(jnp.float32)
    h = h0
    ys = []
    for t in range(S):
        dA = jnp.exp(dt[:, t, :, None] * A)
        dBx = (dt[:, t] * xf[:, t])[..., None] * Bm[:, t, None, :]
        h = dA * h + dBx
        ys.append(jnp.einsum("bn,bdn->bd", Cm[:, t], h))
    y = jnp.stack(ys, axis=1) + xf * p["D"]
    return y, h


class TestMamba1:
    @pytest.fixture(scope="class")
    def setup(self):
        cfg = get_arch("falcon-mamba-7b").reduced()
        p = tree_init(ssm_mod.mamba1_specs(cfg), jax.random.PRNGKey(7))
        return cfg, p

    def test_chunked_matches_sequential(self, setup):
        cfg, p = setup
        B, S = 2, 96
        x = _rand(jax.random.PRNGKey(8), (B, S, cfg.d_inner))
        h0 = jnp.zeros((B, cfg.d_inner, cfg.ssm_state), jnp.float32)
        y_c, h_c = ssm_mod._mamba1_core(p, cfg, x, h0)
        y_s, h_s = _mamba1_sequential(p, cfg, x, h0)
        np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_s), atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(h_c), np.asarray(h_s), atol=1e-4, rtol=1e-4)

    def test_forward_decode_consistency(self, setup):
        """Forward over S tokens == S single-token decode steps."""
        cfg, p = setup
        B, S = 1, 16
        u = _rand(jax.random.PRNGKey(9), (B, S, cfg.d_model))
        y_fwd, _ = ssm_mod.mamba1_forward(p, cfg, u)
        h = jnp.zeros((B, cfg.d_inner, cfg.ssm_state), jnp.float32)
        conv = jnp.zeros((B, cfg.ssm_conv - 1, cfg.d_inner), u.dtype)
        outs = []
        for t in range(S):
            y, h, conv = ssm_mod.mamba1_decode(p, cfg, u[:, t], h, conv)
            outs.append(y)
        y_dec = jnp.stack(outs, axis=1)
        np.testing.assert_allclose(np.asarray(y_fwd), np.asarray(y_dec), atol=1e-4, rtol=1e-3)


def _mamba2_sequential(cfg, dt, A, Bm, Cm, X, h0):
    B, S, H = dt.shape
    h = h0
    ys = []
    for t in range(S):
        dA = jnp.exp(dt[:, t] * A)  # (B,H)
        h = dA[..., None, None] * h + jnp.einsum("bn,bh,bhp->bhpn", Bm[:, t], dt[:, t], X[:, t])
        ys.append(jnp.einsum("bn,bhpn->bhp", Cm[:, t], h))
    return jnp.stack(ys, axis=1), h


class TestMamba2:
    @pytest.fixture(scope="class")
    def setup(self):
        cfg = get_arch("zamba2-2.7b").reduced()
        p = tree_init(ssm_mod.mamba2_specs(cfg), jax.random.PRNGKey(11))
        return cfg, p

    def test_chunked_matches_sequential(self, setup):
        cfg, p = setup
        B, S, H, P, N = 2, 64, cfg.ssm_nheads, cfg.ssm_head_dim, cfg.ssm_state
        key = jax.random.PRNGKey(12)
        dt = jax.nn.softplus(_rand(key, (B, S, H)))
        A = -jnp.exp(_rand(jax.random.fold_in(key, 1), (H,)))
        Bm = _rand(jax.random.fold_in(key, 2), (B, S, N))
        Cm = _rand(jax.random.fold_in(key, 3), (B, S, N))
        X = _rand(jax.random.fold_in(key, 4), (B, S, H, P))
        h0 = jnp.zeros((B, H, P, N), jnp.float32)
        y_c, h_c = ssm_mod._mamba2_core(cfg, dt, A, Bm, Cm, X, h0)
        y_s, h_s = _mamba2_sequential(cfg, dt, A, Bm, Cm, X, h0)
        np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_s), atol=1e-4, rtol=1e-3)
        np.testing.assert_allclose(np.asarray(h_c), np.asarray(h_s), atol=1e-4, rtol=1e-3)

    def test_forward_decode_consistency(self, setup):
        cfg, p = setup
        B, S = 1, 12
        u = _rand(jax.random.PRNGKey(13), (B, S, cfg.d_model))
        y_fwd, _ = ssm_mod.mamba2_forward(p, cfg, u)
        h = jnp.zeros((B, cfg.ssm_nheads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32)
        conv = jnp.zeros((B, cfg.ssm_conv - 1, cfg.d_inner + 2 * cfg.ssm_state), u.dtype)
        outs = []
        for t in range(S):
            y, h, conv = ssm_mod.mamba2_decode(p, cfg, u[:, t], h, conv)
            outs.append(y)
        y_dec = jnp.stack(outs, axis=1)
        np.testing.assert_allclose(np.asarray(y_fwd), np.asarray(y_dec), atol=1e-4, rtol=1e-3)


# ------------------------------------------------------------------ moe
class TestMoE:
    def test_dispatch_matches_dense_oracle(self):
        cfg = get_arch("olmoe-1b-7b").reduced()
        # huge capacity factor -> no drops -> must match dense eval exactly
        cfg = type(cfg)(**{**cfg.__dict__, "capacity_factor": 8.0})
        p = tree_init(moe_specs(cfg), jax.random.PRNGKey(21))
        x = _rand(jax.random.PRNGKey(22), (2, 32, cfg.d_model))
        out, aux = moe_apply(p, cfg, x)
        ref = moe_apply_dense_eval(p, cfg, x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4, rtol=1e-3)
        assert float(aux) > 0

    def test_capacity_drops_are_bounded(self):
        cfg = get_arch("olmoe-1b-7b").reduced()
        p = tree_init(moe_specs(cfg), jax.random.PRNGKey(23))
        x = _rand(jax.random.PRNGKey(24), (2, 64, cfg.d_model))
        out, _ = moe_apply(p, cfg, x)  # cf=1.25: some drops allowed, no NaN
        assert jnp.isfinite(out).all()

    def test_combine_weights_renormalized(self):
        # after renorm, a token routed to k experts with ample capacity gets
        # weights summing to 1 -> output magnitude independent of raw gate mass
        cfg = get_arch("mixtral-8x22b").reduced()
        cfg = type(cfg)(**{**cfg.__dict__, "capacity_factor": 8.0})
        p = tree_init(moe_specs(cfg), jax.random.PRNGKey(25))
        x = _rand(jax.random.PRNGKey(26), (1, 16, cfg.d_model))
        out, _ = moe_apply(p, cfg, x)
        ref = moe_apply_dense_eval(p, cfg, x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4, rtol=1e-3)


# ------------------------------------------------- full-model consistency
def _lm_batch(cfg, B, S, key=0):
    rng = np.random.default_rng(key)
    return {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
    }


DECODE_ARCHS = ["qwen2-0.5b", "h2o-danube-1.8b", "mixtral-8x22b", "falcon-mamba-7b", "zamba2-2.7b"]


class TestDecodeForwardConsistency:
    """Feeding S tokens through decode_step one at a time must reproduce the
    prefill's last-token logits — exercises KV/SSM caches end to end."""

    @pytest.mark.parametrize("arch", DECODE_ARCHS)
    def test_decode_chain_matches_prefill(self, arch):
        cfg = get_arch(arch).reduced()
        if cfg.family == "moe":
            # ample capacity: prefill-time capacity drops (a training-time
            # semantic) would otherwise legitimately diverge from decode,
            # which never drops (T=1 per step)
            cfg = type(cfg)(**{**cfg.__dict__, "capacity_factor": 8.0})
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(31))
        B, S = 1, 16
        batch = _lm_batch(cfg, B, S)
        logits_pre, _ = jax.jit(m.prefill)(params, {"tokens": batch["tokens"]})

        cache = m.init_cache(B, S)
        step = jax.jit(m.decode_step)
        for t in range(S):
            logits_dec, cache = step(params, batch["tokens"][:, t], cache, jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(logits_dec), np.asarray(logits_pre), atol=5e-3, rtol=5e-3
        )


class TestAllArchSmoke:
    """Deliverable (f): every assigned arch instantiates reduced and runs a
    forward/train step with finite outputs. (Full configs only via dry-run.)"""

    @pytest.mark.parametrize("arch", list_archs())
    def test_loss_finite_and_grads_flow(self, arch):
        cfg = get_arch(arch).reduced()
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(41))
        B, S = 2, 64
        rng = np.random.default_rng(5)
        if cfg.family == "encoder":
            batch = {
                "frame_embeds": jnp.asarray(rng.normal(size=(B, S, cfg.d_model)), jnp.float32),
                "mask": jnp.asarray(rng.random((B, S)) < 0.3),
                "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
            }
        elif cfg.family == "vlm":
            si = S // 2
            batch = {
                "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S - si)), jnp.int32),
                "patch_embeds": jnp.asarray(rng.normal(size=(B, si, cfg.d_model)), jnp.float32),
                "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S - si)), jnp.int32),
            }
        else:
            batch = _lm_batch(cfg, B, S)

        def loss_fn(p):
            return m.loss(p, batch)[0]

        loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
        assert jnp.isfinite(loss), arch
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
        )
        assert jnp.isfinite(gnorm) and float(gnorm) > 0, arch

    @pytest.mark.parametrize("arch", list_archs())
    def test_param_count_close_to_nameplate(self, arch):
        cfg = get_arch(arch)
        n = param_count(build_model(cfg).param_specs())
        nameplate = {
            "qwen1.5-110b": 111e9, "qwen2-0.5b": 0.49e9, "glm4-9b": 9.4e9,
            "h2o-danube-1.8b": 1.8e9, "mixtral-8x22b": 141e9, "olmoe-1b-7b": 6.9e9,
            "llava-next-34b": 34e9, "zamba2-2.7b": 2.6e9, "hubert-xlarge": 1e9,
            "falcon-mamba-7b": 7.3e9,
        }[arch]
        assert abs(n - nameplate) / nameplate < 0.30, (arch, n, nameplate)
