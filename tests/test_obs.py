"""Observability plane units (DESIGN.md §11): deterministic tracer, typed
metrics + shims, the PHI redaction contract, and the Clock protocol every
clock-consuming layer is held to."""
import hashlib
import json
import logging

import pytest

from repro.obs import (
    NULL_TRACER,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    NullTracer,
    Redactor,
    StatsShim,
    Tracer,
    export_metrics_jsonl,
    export_spans_jsonl,
    to_chrome_trace,
    trace_id_for,
)
from repro.obs.export import ALLOWED_ATTR_KEYS, REDACTED
from repro.utils.logging import KvFormatter, get_logger, kv
from repro.utils.timing import Clock, SimClock, Timer, WallClock


# ------------------------------------------------------------------- tracer
class TestTracer:
    def _scripted(self):
        clock = SimClock()
        tracer = Tracer(clock)
        with tracer.span("service.submit", n=3):
            clock.advance(1.5)
            with tracer.span("planner.partition", cohort_id=1) as inner:
                clock.advance(0.25)
                inner.set(cold=2)
        tracer.event("broker.publish", trace_id=trace_id_for("IRB/A", 1), key="IRB/A")
        return tracer

    def test_digest_is_bit_identical_across_runs(self):
        assert self._scripted().digest() == self._scripted().digest()

    def test_digest_moves_with_any_change(self):
        base = self._scripted()
        other = self._scripted()
        other.event("extra.event")
        assert base.digest() != other.digest()

    def test_ids_are_deterministic_not_random(self):
        tracer = self._scripted()
        assert [s.span_id for s in tracer.spans()] == ["s00000002", "s00000001", "s00000003"]
        assert trace_id_for("IRB/A", 1) == trace_id_for("IRB/A", 1)
        assert trace_id_for("IRB/A", 1) != trace_id_for("IRB/A", 2)
        assert trace_id_for("IRB/A") == hashlib.sha256(b"trace|IRB/A|1").hexdigest()[:16]

    def test_stack_parenting_and_trace_inheritance(self):
        tracer = self._scripted()
        root = tracer.spans("service.submit")[0]
        child = tracer.spans("planner.partition")[0]
        assert root.parent_id is None
        assert child.parent_id == root.span_id
        assert child.trace_id == root.trace_id  # inherited
        assert child.t0 == 1.5 and child.t1 == 1.75
        assert root.t0 == 0.0 and root.t1 == 1.75

    def test_explicit_trace_id_breaks_parent_linkage(self):
        clock = SimClock()
        tracer = Tracer(clock)
        with tracer.span("outer"):
            with tracer.span("inner", trace_id=trace_id_for("K", 2)) as h:
                pass
        inner = tracer.spans("inner")[0]
        # different trace: no cross-trace parent pointer
        assert inner.parent_id is None
        assert inner.trace_id == trace_id_for("K", 2)

    def test_exception_recorded_and_propagated(self):
        tracer = Tracer(SimClock())
        with pytest.raises(ValueError):
            with tracer.span("doomed"):
                raise ValueError("boom")
        span = tracer.spans("doomed")[0]
        assert span.attrs["error"] == "ValueError"
        assert tracer.open_count == 0

    def test_event_is_zero_duration(self):
        tracer = self._scripted()
        ev = tracer.spans("broker.publish")[0]
        assert ev.duration == 0.0 and ev.t0 == ev.t1

    def test_null_tracer_is_inert(self):
        handle = NULL_TRACER.span("anything", key="x")
        with handle as h:
            h.set(n=1)
        assert NULL_TRACER.spans() == []
        assert NULL_TRACER.open_count == 0
        assert NULL_TRACER.digest() == hashlib.sha256(b"").hexdigest()
        assert isinstance(NULL_TRACER, NullTracer)
        # never touches a clock (it has none to touch)
        assert NULL_TRACER.clock is None


# ------------------------------------------------------------------ metrics
class TestMetrics:
    def test_name_convention_is_enforced(self):
        with pytest.raises(ValueError):
            Counter("not_namespaced")
        with pytest.raises(ValueError):
            Counter("repro_Upper_bad")

    def test_counter_labels_and_value(self):
        c = Counter("repro_test_hits")
        c.inc()
        c.inc(2, modality="CT")
        c.inc(1, modality="MR")
        assert c.value == 1
        assert c.series() == {"": 1, '{modality="CT"}': 2, '{modality="MR"}': 1}

    def test_gauge_set_inc_dec(self):
        g = Gauge("repro_test_depth")
        g.set(5)
        g.inc(2)
        g.dec()
        assert g.value == 6

    def test_histogram_buckets_cumulative_in_snapshot(self):
        reg = MetricsRegistry()
        h = Histogram("repro_test_latency", registry=reg, buckets=(0.1, 1.0))
        for v in (0.05, 0.5, 0.5, 5.0):
            h.observe(v)
        snap = reg.snapshot()
        assert snap["repro_test_latency_count"] == 4
        assert snap["repro_test_latency_sum"] == pytest.approx(6.05)
        assert snap['repro_test_latency_bucket{le="0.1"}'] == 1
        assert snap['repro_test_latency_bucket{le="1.0"}'] == 3
        assert snap['repro_test_latency_bucket{le="+Inf"}'] == 4

    def test_registry_sums_across_family_instances(self):
        """Prometheus multiprocess model: many components own the same
        family; the registry aggregates while each instance stays exact."""
        reg = MetricsRegistry()
        a = Counter("repro_test_runs", registry=reg)
        b = Counter("repro_test_runs", registry=reg)
        a.inc(3)
        b.inc(4)
        assert a.value == 3 and b.value == 4
        assert reg.value("repro_test_runs") == 7
        assert reg.snapshot()["repro_test_runs"] == 7

    def test_snapshot_is_sorted_and_deterministic(self):
        reg = MetricsRegistry()
        Counter("repro_test_b", registry=reg).inc()
        Counter("repro_test_a", registry=reg).inc()
        keys = list(reg.snapshot())
        assert keys == sorted(keys)


class _DemoStats(StatsShim):
    _SUBSYSTEM = "demo"
    _FIELDS = ("hits", "misses")


class TestStatsShim:
    def test_attribute_surface_routes_to_counters(self):
        s = _DemoStats()
        s.hits += 3
        s.misses = 2
        assert (s.hits, s.misses) == (3, 2)
        assert isinstance(s.hits, int)
        assert s.as_dict() == {"hits": 3, "misses": 2}
        assert "hits=3" in repr(s)

    def test_shared_registry_aggregation(self):
        reg = MetricsRegistry()
        s1, s2 = _DemoStats(reg), _DemoStats(reg)
        s1.hits += 1
        s2.hits += 5
        assert reg.value("repro_demo_hits") == 6
        assert s1.hits == 1  # per-instance reads stay exact

    def test_standalone_shim_gets_private_registry(self):
        s = _DemoStats()
        assert s.registry.value("repro_demo_hits") == 0
        s.hits += 1
        assert s.registry.value("repro_demo_hits") == 1

    def test_unknown_field_raises(self):
        with pytest.raises(AttributeError):
            _DemoStats().nope


# ---------------------------------------------------------------- redaction
class TestRedactor:
    def test_non_allowlisted_keys_are_dropped(self):
        red = Redactor()
        out = red.attrs({"key": "IRB/A", "note": "patient=DOE^JOHN"})
        assert out == {"key": "IRB/A"}  # 'note' dropped, key AND value

    def test_free_text_values_blocked_even_on_allowed_keys(self):
        red = Redactor()
        assert red.safe_value("DOE^JOHN") == REDACTED
        assert red.safe_value("two words") == REDACTED
        assert red.safe_value("x" * 65) == REDACTED
        assert red.safe_value("IRB-T/SIM0001#3") == "IRB-T/SIM0001#3"
        assert red.safe_value(17) == 17
        assert red.safe_value(None) is None
        assert red.safe_value(["ok", "BAD VALUE"]) == ["ok", REDACTED]

    def test_disabled_passthrough_exists_for_negative_control(self):
        red = Redactor(enabled=False)
        attrs = {"note": "patient=DOE^JOHN"}
        assert red.attrs(attrs) == attrs

    def test_every_allowed_key_is_a_code_literal(self):
        assert all(k.isidentifier() for k in ALLOWED_ATTR_KEYS)

    def test_length_boundary_is_exactly_64(self):
        red = Redactor()
        assert red.safe_value("x" * 64) == "x" * 64
        assert red.safe_value("x" * 65) == REDACTED

    def test_unicode_and_control_chars_redacted(self):
        red = Redactor()
        assert red.safe_value("pâtient") == REDACTED
        assert red.safe_value("名前") == REDACTED
        assert red.safe_value("a\x00b") == REDACTED
        assert red.safe_value("a\nb") == REDACTED
        assert red.safe_value("a\tb") == REDACTED
        assert red.safe_value("") == REDACTED  # empty fails the 1-char floor

    def test_nested_list_values_recurse(self):
        red = Redactor()
        out = red.safe_value([["ok", "DOE^JOHN"], ("also_ok",)])
        assert out == [["ok", REDACTED], ["also_ok"]]
        # dict-valued attrs are opaque: redact wholesale, never key-by-key
        assert red.safe_value({"PatientName": "DOE^JOHN"}) == REDACTED

    def test_slo_profile_plane_keys_allowlisted(self):
        red = Redactor()
        attrs = {
            "modality": "CT", "slo": "cold_serve_CT", "rule": 0,
            "action": "fire", "severity": "page",
            "burn_long": 6.25, "burn_short": 7.5,
        }
        assert red.attrs(attrs) == attrs

    def test_slo_plane_keys_still_redact_planted_phi_values(self):
        red = Redactor()
        out = red.attrs({"modality": "DOE^JOHN", "slo": "x" * 65,
                         "action": "patient left AMA"})
        assert out == {"modality": REDACTED, "slo": REDACTED,
                       "action": REDACTED}


class TestExport:
    def _traced(self):
        clock = SimClock()
        tracer = Tracer(clock)
        with tracer.span("worker.process", trace_id=trace_id_for("IRB/A", 1),
                         key="IRB/A", note="patient=DOE^JOHN") as sp:
            clock.advance(2.0)
            sp.set(ok=True)
        return tracer

    def test_spans_jsonl_is_redacted_and_parseable(self):
        tracer = self._traced()
        text = export_spans_jsonl(tracer.spans(), Redactor())
        assert "DOE^JOHN" not in text
        (rec,) = [json.loads(line) for line in text.splitlines()]
        assert rec["name"] == "worker.process"
        assert rec["attrs"] == {"key": "IRB/A", "ok": True}

    def test_metrics_jsonl_redacts_label_values(self):
        reg = MetricsRegistry()
        c = Counter("repro_test_scans", registry=reg)
        c.inc(1, device="GE MEDICAL^SYS")
        text = export_metrics_jsonl(reg.snapshot(), Redactor())
        (rec,) = [json.loads(line) for line in text.splitlines()]
        assert rec["metric"] == "repro_test_scans"
        assert rec["labels"]["device"] == REDACTED
        assert rec["value"] == 1

    def test_chrome_trace_shape(self):
        tracer = self._traced()
        doc = to_chrome_trace(tracer.spans(), Redactor())
        assert doc["displayTimeUnit"] == "ms"
        slices = [e for e in doc["traceEvents"] if e["ph"] == "X"]
        assert slices and slices[0]["dur"] == pytest.approx(2e6)
        assert "note" not in slices[0]["args"]
        assert any(e["ph"] == "M" for e in doc["traceEvents"])  # thread names


# ----------------------------------------------------------- clock protocol
class TestClockProtocol:
    """Satellite: every clock-consuming layer accepts both SimClock and
    WallClock through the structural Clock protocol."""

    def test_both_clocks_satisfy_protocol(self):
        assert isinstance(SimClock(), Clock)
        assert isinstance(WallClock(), Clock)

    @pytest.mark.parametrize("make_clock", [SimClock, WallClock])
    def test_broker_and_autoscaler_accept_either_clock(self, make_clock):
        from repro.queueing import Autoscaler, AutoscalerConfig, Broker

        clock = make_clock()
        broker = Broker(clock, visibility_timeout=60.0)
        broker.publish(key="IRB/A", payload={"accession": "A"}, nbytes=10)
        scaler = Autoscaler(broker, AutoscalerConfig(), clock)
        assert scaler.tick() >= 1
        (msg,) = broker.pull("w0", max_messages=1)
        broker.ack(msg.msg_id)
        assert broker.empty()

    @pytest.mark.parametrize("make_clock", [SimClock, WallClock])
    def test_tracer_accepts_either_clock(self, make_clock):
        tracer = Tracer(make_clock())
        with tracer.span("x"):
            pass
        (span,) = tracer.spans()
        assert span.t1 >= span.t0

    def test_timer_is_reentrant(self):
        clock = SimClock()
        t = Timer(clock)
        with t:
            clock.advance(5.0)
            with t:
                clock.advance(1.0)
            assert t.seconds == 1.0  # inner region, not a clobbered outer
            clock.advance(2.0)
        assert t.seconds == 8.0  # outer region survived the nesting

    def test_timer_wallclock_default_still_works(self):
        with Timer() as t:
            pass
        assert t.seconds >= 0.0


# ------------------------------------------------------------------ logging
class TestLoggingShim:
    def test_configuration_is_idempotent(self):
        get_logger("obs.test")
        root = logging.getLogger("repro")
        marked = [h for h in root.handlers if getattr(h, "_repro_kv_handler", False)]
        get_logger("obs.test2")
        get_logger("obs.test3")
        marked_after = [
            h for h in root.handlers if getattr(h, "_repro_kv_handler", False)
        ]
        assert len(marked) == len(marked_after) == 1

    def test_level_rereads_env_instead_of_latching(self, monkeypatch):
        monkeypatch.setenv("REPRO_LOG", "DEBUG")
        get_logger("obs.lvl")
        assert logging.getLogger("repro").level == logging.DEBUG
        monkeypatch.setenv("REPRO_LOG", "WARNING")
        get_logger("obs.lvl")  # same process, new level applied
        assert logging.getLogger("repro").level == logging.WARNING

    def test_kv_formatter_appends_sorted_pairs(self):
        fmt = KvFormatter("%(message)s")
        record = logging.LogRecord(
            "repro.t", logging.INFO, __file__, 1, "served", None, None
        )
        record.kv = kv(cohort=4, accession="SIM0001")["kv"]
        assert fmt.format(record) == "served accession=SIM0001 cohort=4"

    def test_kv_helper_shape(self):
        assert kv(a=1) == {"kv": {"a": 1}}
