"""Pipelined executor properties (DESIGN.md §12): payload bytes and trace
digests are invariant to host-pool size and pipeline depth, and a crash
mid-overlap never leaks a partial result batch."""
import numpy as np
import pytest

from repro.core.batch import BatchedDeidExecutor
from repro.dicom import codec
from repro.obs.trace import Tracer
from repro.utils.timing import SimClock


def _mk_items(rng, n=10):
    items = []
    for i in range(n):
        shape = (60, 80) if i % 3 else (48, 48)
        dtype = np.uint16 if i % 2 else np.uint8
        px = rng.integers(0, np.iinfo(dtype).max, size=shape).astype(dtype)
        items.append((px, [(4, 4, 24, 8)] if i % 4 else []))
    return items


def _run(ex, items, sv=2):
    return ex.run([(px.copy(), rl) for px, rl in items], sv=sv)


class TestOverlapDeterminism:
    @pytest.mark.parametrize("use_kernel", [False, True])
    def test_bytes_identical_across_pool_and_depth(self, rng, use_kernel):
        items = _mk_items(rng)
        ref = None
        for host_workers in (0, 1, 3):
            for depth in (1, 2, 4):
                ex = BatchedDeidExecutor(
                    max_batch=4,
                    use_kernel=use_kernel,
                    interpret=True if use_kernel else None,
                    host_workers=host_workers,
                    pipeline_depth=depth,
                )
                outs = _run(ex, items)
                payloads = [o.payload for o in outs]
                pixels = [o.pixels.tobytes() for o in outs]
                if ref is None:
                    ref = (payloads, pixels)
                else:
                    assert (payloads, pixels) == ref, (host_workers, depth)
                ex.close()

    def test_trace_digest_invariant_to_pool_size(self, rng):
        items = _mk_items(rng, n=7)
        digests = set()
        for host_workers in (0, 3):
            tracer = Tracer(SimClock())
            ex = BatchedDeidExecutor(
                max_batch=4,
                use_kernel=False,
                host_workers=host_workers,
                pipeline_depth=2,
                tracer=tracer,
            )
            _run(ex, items)
            assert tracer.spans("kernel.entropy_code")  # the tail was traced
            digests.add(tracer.digest())
            ex.close()
        assert len(digests) == 1

    def test_entropy_span_carries_boundary_timing(self, rng):
        tracer = Tracer(SimClock())
        ex = BatchedDeidExecutor(
            max_batch=4, use_kernel=False, host_workers=0, tracer=tracer
        )
        _run(ex, _mk_items(rng, n=4))
        spans = tracer.spans("kernel.entropy_code")
        assert spans
        for sp in spans:
            assert "queue_s" in sp.attrs and "wait_s" in sp.attrs
            assert "bytes_out" in sp.attrs
        # dispatch and entropy spans alternate as siblings — the overlap
        # window is dispatch(N+1).t0 < entropy(N).t1 in wall-clock traces
        assert len(tracer.spans("kernel.dispatch")) == len(spans)

    def test_batched_equals_serial_oracle_end_to_end(self, gen, pseudo_overlap):
        from repro.core import DeidPipeline, build_request

        s = gen.gen_study("OVL-US", modality="US", n_images=6)
        req = build_request(pseudo_overlap, s.accession, s.mrn)
        batched = DeidPipeline()
        batched.executor.pipeline_depth = 3
        batched.executor.host_workers = 2
        serial = DeidPipeline(batched=False)
        out_b, man_b = batched.process_study(s, req, "w0")
        out_s, man_s = serial.process_study(s, req, "w0")
        assert man_b.to_json() == man_s.to_json()
        for a, b in zip(out_b, out_s):
            np.testing.assert_array_equal(a.pixels, b.pixels)


@pytest.fixture()
def pseudo_overlap():
    from repro.core import PseudonymService, TrustMode

    return PseudonymService("IRB-OVL", TrustMode.POST_IRB, key=b"y" * 32)


class TestCrashMidOverlap:
    def test_no_partial_batch_escapes(self, rng, monkeypatch):
        items = _mk_items(rng, n=12)
        calls = {"n": 0}
        real_encode = codec.rice_encode

        def flaky_encode(res):
            calls["n"] += 1
            if calls["n"] == 7:  # mid-run: some chunks already collected
                raise RuntimeError("entropy coder died mid-overlap")
            return real_encode(res)

        monkeypatch.setattr(codec, "rice_encode", flaky_encode)
        ex = BatchedDeidExecutor(
            max_batch=4, use_kernel=False, host_workers=3, pipeline_depth=3
        )
        with pytest.raises(RuntimeError, match="mid-overlap"):
            _run(ex, items)
        # nothing escaped: run() raised instead of returning a partial list,
        # and the executor (and its pool) stays usable for the next study
        monkeypatch.setattr(codec, "rice_encode", real_encode)
        outs = _run(ex, items)
        assert all(o.payload is not None for o in outs)
        ref = BatchedDeidExecutor(max_batch=4, use_kernel=False, host_workers=0)
        for a, b in zip(outs, _run(ref, items)):
            assert a.payload == b.payload
        ex.close()

    def test_inline_mode_crash_equivalent(self, rng, monkeypatch):
        # same failure with no pool: identical exception surface
        items = _mk_items(rng, n=6)

        def boom(res):
            raise RuntimeError("entropy coder died")

        monkeypatch.setattr(codec, "rice_encode", boom)
        ex = BatchedDeidExecutor(max_batch=4, use_kernel=False, host_workers=0)
        with pytest.raises(RuntimeError, match="died"):
            _run(ex, items)
