"""Property-based tests (hypothesis) on system invariants.

Skips cleanly where hypothesis isn't installed (the seeded-random sweeps in
test_fused_kernel.py cover the fused-kernel properties without it)."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import PseudonymService, TrustMode, numpy_blank
from repro.core.rules import parse_scrub_script
from repro.dicom import codec
from repro.kernels.fused.ops import fused_scrub_residuals
from repro.kernels.scrub.ops import pack_rects, scrub_images
from repro.queueing import Autoscaler, AutoscalerConfig, Broker
from repro.utils.bytesize import human_bytes, parse_bytes
from repro.utils.timing import SimClock

_settings = settings(max_examples=25, deadline=None, suppress_health_check=[HealthCheck.too_slow])

rects_st = st.lists(
    st.tuples(
        st.integers(0, 90), st.integers(0, 60), st.integers(0, 120), st.integers(0, 80)
    ),
    min_size=0,
    max_size=4,
)


class TestScrubProperties:
    @given(rects=rects_st, seed=st.integers(0, 2**31 - 1))
    @_settings
    def test_idempotent_and_monotone(self, rects, seed):
        """Scrubbing twice == scrubbing once; scrubbed pixels are only ever
        cleared, never invented; pixels outside all rects are untouched."""
        rng = np.random.default_rng(seed)
        img = (rng.random((64, 96)) * 4000).astype(np.uint16)
        once = numpy_blank(img, rects)
        twice = numpy_blank(once, rects)
        np.testing.assert_array_equal(once, twice)
        assert (once <= img).all()
        mask = np.zeros_like(img, bool)
        for x, y, w, h in rects:
            mask[y : y + h, x : x + w] = True
        np.testing.assert_array_equal(once[~mask], img[~mask])
        assert (once[mask] == 0).all()

    @given(rects=rects_st, seed=st.integers(0, 2**31 - 1))
    @_settings
    def test_kernel_equals_reference(self, rects, seed):
        rng = np.random.default_rng(seed)
        img = (rng.random((2, 64, 96)) * 250).astype(np.uint8)
        packed = pack_rects([rects, rects])
        out = np.asarray(scrub_images(jnp.asarray(img), packed))
        ref = np.stack([numpy_blank(img[i], rects) for i in range(2)])
        np.testing.assert_array_equal(out, ref)


class TestFusedKernelProperties:
    @given(rects=rects_st, seed=st.integers(0, 2**31 - 1), sv=st.integers(1, 7))
    @_settings
    def test_fused_equals_two_pass_oracle(self, rects, seed, sv):
        """Fused scrub+JLS == numpy_blank -> codec.residuals, bit-exact."""
        rng = np.random.default_rng(seed)
        img = (rng.random((2, 64, 96)) * 4000).astype(np.uint16)
        packed = pack_rects([rects, rects])
        got = np.asarray(fused_scrub_residuals(img, packed, sv=sv))
        want = np.stack([codec.residuals(numpy_blank(img[i], rects), sv) for i in range(2)])
        np.testing.assert_array_equal(got, want)


class TestCodecProperties:
    @given(
        seed=st.integers(0, 2**31 - 1),
        sv=st.sampled_from([1, 2, 4, 7]),
        h=st.integers(4, 40),
        w=st.integers(4, 40),
        bits=st.sampled_from([8, 16]),
    )
    @_settings
    def test_roundtrip_exact(self, seed, sv, h, w, bits):
        rng = np.random.default_rng(seed)
        dtype = np.uint8 if bits == 8 else np.uint16
        img = (rng.random((h, w)) * ((1 << bits) - 1)).astype(dtype)
        np.testing.assert_array_equal(codec.decode(codec.encode(img, sv)), img)

    @given(seed=st.integers(0, 2**31 - 1))
    @_settings
    def test_smooth_images_compress(self, seed):
        rng = np.random.default_rng(seed)
        ramp = np.cumsum(rng.integers(0, 3, (64, 64)), axis=1).astype(np.uint16)
        assert codec.compression_ratio(ramp) > 1.5


class TestPseudonymProperties:
    @given(values=st.lists(st.text(min_size=1, max_size=20), min_size=2, max_size=20, unique=True))
    @_settings
    def test_injective_on_distinct_inputs(self, values):
        svc = PseudonymService("IRB-P", TrustMode.POST_IRB, key=b"p" * 32)
        codes = [svc.accession(v) for v in values]
        assert len(set(codes)) == len(values)

    @given(
        mrn=st.text(min_size=1, max_size=16),
        da=st.dates(min_value=__import__("datetime").date(1900, 1, 1),
                    max_value=__import__("datetime").date(2099, 12, 31)),
    )
    @_settings
    def test_jitter_roundtrips_through_dates(self, mrn, da):
        svc = PseudonymService("IRB-P", TrustMode.POST_IRB, key=b"p" * 32)
        j = svc.jitter_for(mrn)
        s = da.strftime("%Y%m%d")
        out = PseudonymService.jitter_date(s, j)
        back = PseudonymService.jitter_date(out, -j)
        assert back == s and out != s


class TestQueueProperties:
    @given(
        n=st.integers(1, 30),
        vis=st.floats(1.0, 60.0),
        seed=st.integers(0, 1000),
    )
    @_settings
    def test_conservation(self, n, vis, seed):
        """Messages are never lost or duplicated: acked + available + leased +
        dead == published, at every step of an arbitrary schedule."""
        rng = np.random.default_rng(seed)
        clock = SimClock()
        b = Broker(clock, visibility_timeout=vis, max_deliveries=3)
        for i in range(n):
            b.publish(f"k{i}", {}, nbytes=1)
        for _ in range(100):
            op = rng.integers(0, 4)
            if op == 0:
                b.pull(f"w{rng.integers(3)}")
            elif op == 1 and b._leased:
                b.ack(int(rng.choice(list(b._leased))))
            elif op == 2 and b._leased:
                b.nack(int(rng.choice(list(b._leased))))
            else:
                clock.advance(float(rng.random() * vis))
            s = b.stats()
            assert b.total_acked + s.available + s.leased + s.dead_lettered == n

    @given(backlog=st.integers(0, 10**13), window=st.floats(60, 7200))
    @_settings
    def test_autoscaler_bounds(self, backlog, window):
        clock = SimClock()
        b = Broker(clock)
        cfg = AutoscalerConfig(delivery_window=window, max_instances=64)
        a = Autoscaler(b, cfg, clock)
        t = a.target_for(backlog)
        assert cfg.min_instances <= t <= cfg.max_instances
        if backlog == 0:
            assert t == cfg.min_instances


class TestUtilProperties:
    @given(n=st.integers(0, 10**15))
    @_settings
    def test_bytes_roundtrip_monotone(self, n):
        s = human_bytes(n)
        approx = parse_bytes(s)
        assert abs(approx - n) <= max(0.01 * n, 1000)
