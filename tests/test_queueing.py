"""Queue/autoscaler/worker tests: delivery semantics, fault tolerance,
exactly-once effect, straggler mitigation, checkpoint/restart."""
import pytest

from repro.core import DeidPipeline, PseudonymService, TrustMode
from repro.dicom.generator import StudyGenerator
from repro.queueing import (
    Autoscaler,
    AutoscalerConfig,
    Broker,
    DeidWorker,
    FailureInjector,
    Journal,
    WorkerPool,
)
from repro.queueing.server import DeidService, RequestState
from repro.storage.object_store import StudyStore
from repro.utils.timing import SimClock


def _env(tmp_path, n_studies=4, seed=7, n_images=2, clock=None):
    """Build a small lake + service + pool environment."""
    clock = clock or SimClock()
    gen = StudyGenerator(seed)
    lake = StudyStore("lake", key=b"lake-key")
    mrns = {}
    for i in range(n_studies):
        acc = f"ACC{i:04d}"
        s = gen.gen_study(acc, modality="CT", n_images=n_images)
        lake.put_study(acc, s)
        mrns[acc] = s.mrn
    broker = Broker(clock, visibility_timeout=30.0)
    journal = Journal(tmp_path / "journal.jsonl")
    service = DeidService(broker, lake, journal)
    service.register_study("IRB-9", TrustMode.POST_IRB)
    dest = StudyStore("researcher")
    pipeline = DeidPipeline(recompress=False)

    def make_worker(wid: str) -> DeidWorker:
        return DeidWorker(wid, pipeline, lake, dest, journal)

    return clock, broker, journal, service, dest, make_worker, mrns


class TestBroker:
    def test_lease_ack_lifecycle(self):
        b = Broker(SimClock(), visibility_timeout=10)
        b.publish("k1", {"x": 1}, nbytes=100)
        msgs = b.pull("w0")
        assert len(msgs) == 1 and b.stats().leased == 1
        assert b.ack(msgs[0].msg_id)
        assert b.empty()

    def test_lease_expiry_redelivers(self):
        clock = SimClock()
        b = Broker(clock, visibility_timeout=10)
        b.publish("k1", {}, nbytes=1)
        b.pull("w0")
        clock.advance(11)
        msgs = b.pull("w1")
        assert len(msgs) == 1 and msgs[0].deliveries == 2
        assert b.total_redelivered == 1

    def test_dead_letter_after_max_deliveries(self):
        clock = SimClock()
        b = Broker(clock, visibility_timeout=5, max_deliveries=3)
        b.publish("poison", {}, nbytes=1)
        for _ in range(3):
            b.pull("w0")
            clock.advance(6)
        b.pull("w0")
        assert b.stats().dead_lettered == 1
        assert b.empty()

    def test_nack_immediate_redelivery(self):
        b = Broker(SimClock())
        b.publish("k", {}, nbytes=1)
        m = b.pull("w0")[0]
        b.nack(m.msg_id)
        assert b.stats().available == 1

    def test_ack_after_expiry_is_noop(self):
        clock = SimClock()
        b = Broker(clock, visibility_timeout=5)
        b.publish("k", {}, nbytes=1)
        m = b.pull("w0")[0]
        clock.advance(6)
        b.pull("w1")
        assert not b.ack(m.msg_id)

    def test_dead_letter_bytes_leave_backlog_on_expiry(self):
        """Regression: DLQ'd payload bytes must not linger in backlog_bytes,
        or the autoscaler would keep instances alive for dead work."""
        clock = SimClock()
        b = Broker(clock, visibility_timeout=5, max_deliveries=3)
        b.publish("poison", {}, nbytes=1000)
        for _ in range(3):  # poison cycles through lease expiry into the DLQ
            b.pull("w0")
            clock.advance(6)
        b.publish("live", {}, nbytes=10)
        s = b.stats()
        assert s.dead_lettered == 1
        assert s.dead_letter_bytes == 1000
        assert s.backlog_bytes == 10  # only the live payload remains

    def test_dead_letter_bytes_leave_backlog_on_nack(self):
        b = Broker(SimClock(), max_deliveries=1)
        b.publish("poison", {}, nbytes=500)
        m = b.pull("w0")[0]
        b.nack(m.msg_id)  # delivery budget exhausted -> straight to DLQ
        s = b.stats()
        assert s.dead_lettered == 1 and s.dead_letter_bytes == 500
        assert s.backlog_bytes == 0 and b.empty()


class TestAutoscaler:
    def test_scales_with_backlog_and_window(self):
        clock = SimClock()
        b = Broker(clock)
        cfg = AutoscalerConfig(delivery_window=3600, per_instance_throughput=1e6, max_instances=16)
        a = Autoscaler(b, cfg, clock)
        b.publish("k", {}, nbytes=10 * 3600 * 1_000_000)  # needs 10 instances
        assert a.tick() == 10

    def test_empty_queue_deletes_pool(self):
        clock = SimClock()
        b = Broker(clock)
        a = Autoscaler(b, AutoscalerConfig(min_instances=0), clock)
        b.publish("k", {}, nbytes=10**9)
        assert a.tick() >= 1
        m = b.pull("w0")[0]
        b.ack(m.msg_id)
        assert a.tick() == 0  # paper: instances deleted once queue is empty

    def test_window_pressure_increases_target(self):
        clock = SimClock()
        b = Broker(clock, visibility_timeout=10**6)
        cfg = AutoscalerConfig(delivery_window=1000, per_instance_throughput=1e6, max_instances=1000)
        a = Autoscaler(b, cfg, clock)
        b.publish("k", {}, nbytes=500 * 1_000_000)
        t_early = a.tick()
        clock.advance(900)  # only 100s of window left
        t_late = a.tick()
        assert t_late > t_early

    def test_does_not_scale_against_dead_work(self):
        """Satellite regression: payload bytes that end in the DLQ must not
        keep the autoscaler's target above min_instances."""
        clock = SimClock()
        b = Broker(clock, max_deliveries=1)
        a = Autoscaler(b, AutoscalerConfig(min_instances=0, per_instance_throughput=1e6), clock)
        b.publish("poison", {}, nbytes=10**12)  # would demand max_instances
        assert a.tick() > 0
        m = b.pull("w0")[0]
        b.nack(m.msg_id)  # exhausted delivery budget -> DLQ
        assert b.stats().dead_letter_bytes == 10**12
        assert a.tick() == 0  # dead bytes don't hold the pool up

    def test_cost_accounting(self):
        clock = SimClock()
        b = Broker(clock)
        cfg = AutoscalerConfig(per_instance_throughput=1e6, instance_cost_per_hour=1.0)
        a = Autoscaler(b, cfg, clock)
        b.publish("k", {}, nbytes=3600 * 1_000_000)
        a.tick()
        clock.advance(3600)
        a.tick()
        assert a.cost_usd() == pytest.approx(a.instance_seconds / 3600)

    def test_scale_down_hysteresis(self):
        """A second scale-down inside the cooldown window after the first one
        must be held back (lease churn protection), then allowed once the
        cooldown passes. Scale-ups are never throttled."""
        clock = SimClock()
        b = Broker(clock, visibility_timeout=10**6)
        cfg = AutoscalerConfig(
            delivery_window=10**6, per_instance_throughput=1.0,
            max_instances=100, scale_down_cooldown=120.0,
        )
        a = Autoscaler(b, cfg, clock)

        def swap_backlog(nbytes):
            msg = b.pull("w0")[0]
            b.publish(f"k{nbytes}", {}, nbytes=nbytes)
            b.ack(msg.msg_id)

        b.publish("big", {}, nbytes=9_500_000)
        assert a.tick() == 10
        swap_backlog(4_500_000)
        clock.advance(10)
        assert a.tick() == 5   # first scale-down: never throttled
        swap_backlog(2_200_000)
        clock.advance(10)
        assert a.tick() == 5   # second, 10s later: held by the cooldown
        clock.advance(130)
        assert a.tick() == 3   # cooldown passed: allowed to shrink

    def test_empty_queue_bypasses_cooldown(self):
        """target==0 (pool deletion) ignores the cooldown — the paper deletes
        instances as soon as the queue is empty."""
        clock = SimClock()
        b = Broker(clock)
        cfg = AutoscalerConfig(delivery_window=10**6, per_instance_throughput=1.0,
                               scale_down_cooldown=10**9, max_instances=8)
        a = Autoscaler(b, cfg, clock)
        b.publish("k", {}, nbytes=5 * 10**6)
        assert a.tick() == 5
        m = b.pull("w0")[0]
        b.ack(m.msg_id)
        clock.advance(1)
        assert a.tick() == 0  # cooldown would forbid 5 -> lower, but 0 bypasses

    def test_instance_seconds_irregular_tick_spacing(self):
        """The cost integral is piecewise-constant over whatever tick spacing
        the pool actually produced — including the first tick after a long
        idle gap, which bills the whole gap at the pre-gap pool size."""
        clock = SimClock()
        b = Broker(clock, visibility_timeout=10**9)
        cfg = AutoscalerConfig(
            delivery_window=10**9, per_instance_throughput=1.0,
            max_instances=100, scale_down_cooldown=0.0,
        )
        a = Autoscaler(b, cfg, clock)
        # 3.5 GB over a 1 GB/s-equivalent window: ceil(3.5...) = 4 instances,
        # stable against window-elapsed drift (an exact multiple would tip to
        # 5 as soon as elapsed > 0)
        b.publish("k", {}, nbytes=3_500_000_000)

        a.tick()                      # t=0: 0 -> 4, nothing billed yet
        clock.advance(7)
        a.tick()                      # t=7: bills 4 * 7
        clock.advance(11)
        a.tick()                      # t=18: bills 4 * 11
        m = b.pull("w0")[0]
        b.ack(m.msg_id)               # queue empties; pool still 4 until next tick
        clock.advance(1000)           # long idle gap with no ticks
        a.tick()                      # t=1018: bills 4 * 1000, THEN deletes pool
        clock.advance(50)
        a.tick()                      # t=1068: bills 0 * 50
        assert a.instance_seconds == pytest.approx(4 * (7 + 11 + 1000))
        # the tick log re-integrates to the same number (conformance contract)
        log = a.tick_log
        integral = sum(n * (log[i + 1][0] - log[i][0]) for i, (_, n) in enumerate(log[:-1]))
        assert integral == pytest.approx(a.instance_seconds)

    def test_first_tick_never_bills(self):
        """A first tick after construction has no billing interval, no matter
        how late it happens."""
        clock = SimClock()
        b = Broker(clock)
        a = Autoscaler(b, AutoscalerConfig(), clock)
        clock.advance(10_000)
        a.tick()
        assert a.instance_seconds == 0.0


class TestWorkerPool:
    def test_clean_drain(self, tmp_path):
        clock, broker, journal, service, dest, make_worker, mrns = _env(tmp_path)
        service.submit("IRB-9", list(mrns), mrns)
        pool = WorkerPool(broker, Autoscaler(broker, AutoscalerConfig(), clock), make_worker)
        report = pool.drain()
        assert report.processed == len(mrns)
        assert report.crashes == 0
        assert broker.empty()
        states = service.request_states("IRB-9")
        assert all(s is RequestState.DONE for s in states.values())

    def test_crash_recovery_via_redelivery(self, tmp_path):
        clock, broker, journal, service, dest, make_worker, mrns = _env(tmp_path)
        service.submit("IRB-9", list(mrns), mrns)
        injector = FailureInjector(crash_once_keys=frozenset({f"IRB-9/{a}" for a in list(mrns)[:2]}))
        pool = WorkerPool(broker, Autoscaler(broker, AutoscalerConfig(), clock), make_worker, injector)
        report = pool.drain()
        assert report.crashes == 2
        assert report.redeliveries >= 2
        assert report.processed == len(mrns)  # everything still completed

    def test_exactly_once_under_chaos(self, tmp_path):
        clock, broker, journal, service, dest, make_worker, mrns = _env(tmp_path, n_studies=6)
        service.submit("IRB-9", list(mrns), mrns)
        injector = FailureInjector(crash_rate=0.3)
        pool = WorkerPool(broker, Autoscaler(broker, AutoscalerConfig(), clock), make_worker, injector)
        report = pool.drain()
        merged = journal.merged_manifest("IRB-9")
        # every study completed exactly once despite crashes
        assert journal.completed_keys() == {f"IRB-9/{a}" for a in mrns}
        assert report.processed == len(mrns)

    def test_straggler_speculative_redispatch(self, tmp_path):
        clock, broker, journal, service, dest, make_worker, mrns = _env(tmp_path, n_studies=3)
        broker.visibility_timeout = 10_000  # lease never expires on its own
        service.submit("IRB-9", list(mrns), mrns)
        injector = FailureInjector(straggler_rate=0.4, slow_factor=400.0)
        pool = WorkerPool(
            broker,
            Autoscaler(broker, AutoscalerConfig(), clock),
            make_worker,
            injector,
            straggler_age=60.0,
        )
        report = pool.drain()
        assert report.processed == len(mrns)
        # duplicates from speculation were deduped, not double-delivered
        assert report.deduped == report.speculative or report.speculative == 0

    def test_restart_resumes_from_journal(self, tmp_path):
        clock, broker, journal, service, dest, make_worker, mrns = _env(tmp_path)
        service.submit("IRB-9", list(mrns), mrns)
        # process only the first two messages, then "lose" the pool
        w = make_worker("w0")
        for _ in range(2):
            msg = broker.pull("w0")[0]
            w.process(broker, msg)
        done_before = set(journal.completed_keys())
        assert len(done_before) == 2
        journal.close()

        # restart: fresh journal object from the same file, resubmit everything
        journal2 = Journal(journal.path)
        assert journal2.completed_keys() == done_before
        service2 = DeidService(broker, service.lake, journal2)
        service2.register_study("IRB-9", TrustMode.POST_IRB)
        records = service2.submit("IRB-9", list(mrns), mrns)
        assert sum(1 for r in records if r.state is RequestState.DONE) == 2
        pipeline = DeidPipeline(recompress=False)

        def mw(wid):
            return DeidWorker(wid, pipeline, service.lake, dest, journal2)

        pool = WorkerPool(broker, Autoscaler(broker, AutoscalerConfig(), clock), mw)
        report = pool.drain()
        assert journal2.completed_keys() == {f"IRB-9/{a}" for a in mrns}
        # the two already-done studies were deduped on redelivery, not redone
        assert report.processed == len(mrns) - 2


class TestLeaseHeartbeat:
    def test_extend_lease_pushes_deadline(self):
        clock = SimClock()
        b = Broker(clock, visibility_timeout=10)
        b.publish("k1", {}, nbytes=1)
        msg = b.pull("w0")[0]
        assert b.extend_lease(msg.msg_id, 50.0) is True
        clock.advance(40)  # past the original timeout, inside the extension
        assert b.pull("w1") == []  # not redelivered: the lease is still live
        assert b.ack(msg.msg_id)

    def test_extend_lease_after_expiry_returns_false(self):
        clock = SimClock()
        b = Broker(clock, visibility_timeout=10)
        b.publish("k1", {}, nbytes=1)
        msg = b.pull("w0")[0]
        clock.advance(11)  # lease expired; broker will redeliver
        assert b.extend_lease(msg.msg_id, 100.0) is False
        assert len(b.pull("w1")) == 1  # the redelivery was not blocked

    def test_extend_lease_after_ack_returns_false(self):
        b = Broker(SimClock(), visibility_timeout=10)
        b.publish("k1", {}, nbytes=1)
        msg = b.pull("w0")[0]
        b.ack(msg.msg_id)
        assert b.extend_lease(msg.msg_id, 100.0) is False

    def test_zombie_worker_aborts_instead_of_acking(self, tmp_path):
        """Regression: a worker whose lease expired mid-compute must abort —
        no ack, no journal record, no delivered bytes — because the broker
        already redelivered the work to a new owner."""
        clock, broker, journal, service, dest, make_worker, mrns = _env(
            tmp_path, n_studies=1
        )
        service.submit("IRB-9", list(mrns), mrns)
        msg = broker.pull("w0")[0]
        clock.advance(31)  # visibility_timeout=30: w0 is now a zombie
        w = make_worker("w0")
        w.process(broker, msg)
        assert w.zombie_aborts == 1 and w.processed == 0
        assert not journal.is_done(msg.key)
        assert dest.store.list("out/") == []
        # the redelivered copy completes normally under its own lease
        msg2 = broker.pull("w1")[0]
        w2 = make_worker("w1")
        w2.process(broker, msg2)
        assert w2.processed == 1 and journal.is_done(msg.key)


class TestJournalTornTail:
    def test_truncated_final_record_is_repaired(self, tmp_path):
        from repro.core.manifest import Manifest

        p = tmp_path / "j.jsonl"
        j = Journal(p)
        j.record_done("IRB-9/K1", Manifest("IRB-9"), "w0")
        j.close()
        with open(p, "ab") as fh:  # crash mid-append: partial record, no newline
            fh.write(b'{"kind": "done", "key": "IRB-9/K2", "manif')
        j2 = Journal(p)
        assert j2.completed_keys() == {"IRB-9/K1"}
        assert j2.torn_tail == 1
        # the fragment was truncated away: appends stay line-aligned
        j2.record_done("IRB-9/K3", Manifest("IRB-9"), "w0")
        j2.close()
        j3 = Journal(p)
        assert j3.completed_keys() == {"IRB-9/K1", "IRB-9/K3"}
        assert j3.torn_tail == 0 and j3.corrupt_lines == 0
        j3.close()

    def test_corrupt_mid_file_line_is_skipped_and_counted(self, tmp_path):
        from repro.core.manifest import Manifest

        p = tmp_path / "j.jsonl"
        j = Journal(p)
        j.record_done("IRB-9/K1", Manifest("IRB-9"), "w0")
        j.close()
        with open(p, "ab") as fh:
            fh.write(b"garbage not json\n")  # damaged but newline-terminated
        j2 = Journal(p)
        j2.record_done("IRB-9/K2", Manifest("IRB-9"), "w0")
        j2.close()
        j3 = Journal(p)
        assert j3.completed_keys() == {"IRB-9/K1", "IRB-9/K2"}
        assert j3.corrupt_lines == 1 and j3.torn_tail == 0
        j3.close()


try:
    from hypothesis import HealthCheck, given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs hypothesis
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    from repro.core.manifest import Manifest
    from repro.queueing.broker import Message

    _settings = settings(
        max_examples=30, deadline=None, suppress_health_check=[HealthCheck.too_slow]
    )

    class TestFailureInjectorProperties:
        """The fault model must be a pure function of (worker, key, delivery)
        — that is what makes chaos runs replayable from a seed."""

        @given(
            rate=st.floats(0.0, 1.0),
            worker=st.text(min_size=1, max_size=8),
            key=st.text(min_size=1, max_size=12),
            deliveries=st.integers(1, 6),
        )
        @_settings
        def test_decisions_are_deterministic(self, rate, worker, key, deliveries):
            msg = Message(key=key, payload={}, deliveries=deliveries)
            a = FailureInjector(crash_rate=rate, straggler_rate=rate)
            b = FailureInjector(crash_rate=rate, straggler_rate=rate)
            assert a.should_crash(worker, msg) == b.should_crash(worker, msg)
            assert a.slowdown(worker, msg) == b.slowdown(worker, msg)

        @given(
            r1=st.floats(0.0, 1.0),
            r2=st.floats(0.0, 1.0),
            worker=st.text(min_size=1, max_size=8),
            key=st.text(min_size=1, max_size=12),
            delivery=st.integers(1, 6),
        )
        @_settings
        def test_crash_set_is_monotone_in_rate(self, r1, r2, worker, key, delivery):
            """Raising the crash rate only ever adds (worker, key, delivery)
            crash points, never moves them — schedules at different
            intensities stay comparable."""
            lo, hi = sorted((r1, r2))
            msg = Message(key=key, payload={}, deliveries=delivery)
            crashed_lo = FailureInjector(crash_rate=lo).should_crash(worker, msg)
            crashed_hi = FailureInjector(crash_rate=hi).should_crash(worker, msg)
            assert not crashed_lo or crashed_hi

        @given(
            key=st.text(min_size=1, max_size=12),
            worker=st.text(min_size=1, max_size=8),
        )
        @_settings
        def test_crash_once_keys_crash_exactly_first_delivery(self, key, worker):
            inj = FailureInjector(crash_once_keys=frozenset({key}))
            first = Message(key=key, payload={}, deliveries=1)
            retry = Message(key=key, payload={}, deliveries=2)
            assert inj.should_crash(worker, first)
            assert not inj.should_crash(worker, retry)

    class TestJournalExactlyOnceProperties:
        """Exactly-once effect under randomized crash schedules: however the
        crashes land, every key completes exactly once and record_done
        returns True exactly once per key."""

        @given(
            n_keys=st.integers(1, 6),
            crash_pattern=st.sets(
                st.tuples(st.integers(0, 5), st.integers(1, 3)), max_size=10
            ),
            seed=st.integers(0, 2**31 - 1),
        )
        @_settings
        def test_randomized_crash_schedule(self, tmp_path_factory, n_keys, crash_pattern, seed):
            clock = SimClock()
            broker = Broker(clock, visibility_timeout=10.0, max_deliveries=10)
            journal = Journal(
                tmp_path_factory.mktemp("prop") / f"j{seed}.jsonl"
            )
            keys = [f"K{i}" for i in range(n_keys)]
            for k in keys:
                broker.publish(k, {}, nbytes=1)
            crashes = {(f"K{i}", d) for i, d in crash_pattern if i < n_keys}

            first_acks = 0
            for _ in range(400):  # bounded drain loop
                if broker.empty():
                    break
                msgs = broker.pull("w0", max_messages=1)
                if not msgs:
                    clock.advance(11.0)  # let crashed leases expire
                    continue
                m = msgs[0]
                if (m.key, m.deliveries) in crashes:
                    continue  # crash: no ack, no journal entry
                if not journal.is_done(m.key):
                    if journal.record_done(m.key, Manifest(m.key), "w0"):
                        first_acks += 1
                broker.ack(m.msg_id)
            journal.close()

            assert broker.empty()
            assert journal.completed_keys() == set(keys)
            assert first_acks == n_keys  # each key completed exactly once

        @given(key=st.text(min_size=1, max_size=16))
        @_settings
        def test_record_done_is_idempotent(self, tmp_path_factory, key):
            journal = Journal(tmp_path_factory.mktemp("idem") / "j.jsonl")
            assert journal.record_done(key, Manifest(key), "w0") is True
            assert journal.record_done(key, Manifest(key), "w1") is False
            journal.close()
            replay = Journal(journal.path)
            assert replay.completed_keys() == {key}
            replay.close()


class TestService:
    def test_validation_rejects_unknown_and_optout(self, tmp_path):
        clock, broker, journal, service, dest, make_worker, mrns = _env(tmp_path)
        service.mark_ineligible("ACC0000")
        recs = service.submit("IRB-9", ["ACC0000", "NOPE", "ACC0001"], {**mrns, "NOPE": "0"})
        states = {r.accession: r.state for r in recs}
        assert states["ACC0000"] is RequestState.REJECTED
        assert states["NOPE"] is RequestState.REJECTED
        assert states["ACC0001"] is RequestState.QUEUED

    def test_store_encryption_at_rest(self, tmp_path):
        lake = StudyStore("lake", key=b"secret")
        gen = StudyGenerator(0)
        s = gen.gen_study("E1", modality="CT", n_images=1)
        lake.put_study("E1", s)
        raw = lake.store.raw("studies/E1")
        assert s.mrn.encode() not in raw  # PHI not visible at rest
        assert lake.get_study("E1").mrn == s.mrn
