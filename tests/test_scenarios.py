"""Paper Figure 2b: human-readable regression scenarios, machine-executed.
"If any of these tests fail, the regression test results in failure.\""""
from pathlib import Path

import pytest

from repro.core.scenarios import VirtualDicomTree, parse_feature, run_feature

FEATURES = sorted((Path(__file__).parent / "features").glob("*.feature"))


@pytest.mark.parametrize("path", FEATURES, ids=[p.stem for p in FEATURES])
def test_feature_file(path):
    feature = parse_feature(path.read_text())
    assert feature.scenarios, f"{path} parsed no scenarios"
    results = run_feature(feature, VirtualDicomTree())
    failures = [r for r in results if not r.passed]
    assert not failures, "; ".join(f"{r.scenario}: {r.detail}" for r in failures)


def test_parser_matches_paper_grammar():
    text = (Path(__file__).parent / "features" / "pet_ct.feature").read_text()
    f = parse_feature(text)
    assert f.params["jitter"] == "-6"
    assert f.scripts["anonymizer"] == "stanford-anonymizer.script"
    assert len(f.scenarios) == 3
    # Fig 2b literal scrub rects survive parsing
    rects = [e[1] for e in f.scenarios[1].expectations if e[0] == "scrub_rect"]
    assert rects == [(256, 0, 256, 22), (300, 22, 212, 80), (10, 478, 100, 10)]


def test_failing_scenario_reports():
    bad = """
Feature: failure propagation
Scenario: wrong region expected blank
  Given the DICOM directory "dicom-phi/CT/Anonymize"
  When ran through the deid pipeline
  Then the resulting images should be scrubbed at 400,400,50,50
"""
    feature = parse_feature(bad)
    results = run_feature(feature)
    assert not results[0].passed
