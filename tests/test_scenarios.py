"""Paper Figure 2b: human-readable regression scenarios, machine-executed.
"If any of these tests fail, the regression test results in failure.\""""
from pathlib import Path

import pytest

from repro.core.scenarios import (
    FeatureParseError,
    VirtualDicomTree,
    parse_feature,
    run_feature,
)
from repro.dicom.generator import PROBLEM_KINDS

FEATURES = sorted((Path(__file__).parent / "features").glob("*.feature"))


@pytest.mark.parametrize("path", FEATURES, ids=[p.stem for p in FEATURES])
def test_feature_file(path):
    feature = parse_feature(path.read_text())
    assert feature.scenarios, f"{path} parsed no scenarios"
    results = run_feature(feature, VirtualDicomTree())
    failures = [r for r in results if not r.passed]
    assert not failures, "; ".join(f"{r.scenario}: {r.detail}" for r in failures)


def test_parser_matches_paper_grammar():
    text = (Path(__file__).parent / "features" / "pet_ct.feature").read_text()
    f = parse_feature(text)
    assert f.params["jitter"] == "-6"
    assert f.scripts["anonymizer"] == "stanford-anonymizer.script"
    assert len(f.scenarios) == 3
    # Fig 2b literal scrub rects survive parsing
    rects = [e[1] for e in f.scenarios[1].expectations if e[0] == "scrub_rect"]
    assert rects == [(256, 0, 256, 22), (300, 22, 212, 80), (10, 478, 100, 10)]


def test_failing_scenario_reports():
    bad = """
Feature: failure propagation
Scenario: wrong region expected blank
  Given the DICOM directory "dicom-phi/CT/Anonymize"
  When ran through the deid pipeline
  Then the resulting images should be scrubbed at 400,400,50,50
"""
    feature = parse_feature(bad)
    results = run_feature(feature)
    assert not results[0].passed


class TestMalformedFeatures:
    """A suite author's typo must surface as a clear parse error with the
    offending line, never a crash or a silently skipped step."""

    def _err(self, text) -> FeatureParseError:
        with pytest.raises(FeatureParseError) as ei:
            parse_feature(text)
        return ei.value

    def test_bad_script_step(self):
        err = self._err('Feature: f\nGiven the pipeline uses the filter script missing-quotes')
        assert err.lineno == 2 and "script step" in err.why

    def test_bad_parameter_step(self):
        err = self._err('Feature: f\nAnd script parameter jitter is -6')
        assert err.lineno == 2 and "parameter step" in err.why

    def test_directory_without_quotes(self):
        err = self._err("Feature: f\nScenario: s\n  Given the DICOM directory dicom-phi/CT")
        assert err.lineno == 3 and "quoted path" in err.why

    def test_directory_outside_scenario(self):
        err = self._err('Feature: f\nGiven the DICOM directory "dicom-phi/CT/Anonymize"')
        assert err.lineno == 2 and "outside any Scenario" in err.why

    def test_then_outside_scenario(self):
        err = self._err("Feature: f\nThen the images should be anonymized")
        assert err.lineno == 2 and "outside any Scenario" in err.why

    def test_malformed_scrub_rect(self):
        err = self._err(
            'Feature: f\nScenario: s\n  Given the DICOM directory "dicom-phi/CT/Anonymize"\n'
            "  Then the resulting images should be scrubbed at 10,20,30"
        )
        assert err.lineno == 4 and "scrub expectation" in err.why

    def test_unknown_then_step(self):
        err = self._err(
            'Feature: f\nScenario: s\n  Given the DICOM directory "dicom-phi/CT/Anonymize"\n'
            "  Then the images should be deleted forever"
        )
        assert err.lineno == 4 and err.why == "unknown Then step"

    def test_error_message_carries_context(self):
        err = self._err("Feature: f\nThen the images should be anonymized")
        assert "line 2" in str(err) and "anonymized" in str(err)


@pytest.mark.parametrize("problem", PROBLEM_KINDS)
def test_every_problem_kind_is_filtered(problem):
    """Paper Discussion items 1-3: every categorical exclusion gets its own
    executable scenario through the per-kind virtual directory."""
    text = f"""
Feature: categorical exclusions ({problem})
Scenario: {problem} objects never reach the researcher
  Given the DICOM directory "dicom-phi/CT/Filter/{problem}"
  When ran through the deid pipeline
  Then the images should not pass the filter
"""
    feature = parse_feature(text)
    results = run_feature(feature, VirtualDicomTree())
    assert results[0].passed, results[0].detail


def test_filter_directory_rejects_unknown_kind():
    with pytest.raises(KeyError):
        VirtualDicomTree().resolve("dicom-phi/CT/Filter/not_a_problem")
