"""Fleet-simulator conformance suite (DESIGN.md §7).

Three layers:
* determinism — bit-replayability of event logs and metrics from one seed;
* chaos — crashes, dead-letters, re-ingests, ruleset edits leave every
  invariant green on the REAL stack;
* negative controls — each invariant checker must catch a deliberately
  injected violation (a checker that can't fail is not a check).
"""
import json
import pickle

import pytest

from repro.catalog import Eq, Range
from repro.core.pipeline import build_request
from repro.sim import (
    AuditCompleteness,
    AutoscalerAccounting,
    BurstyTraffic,
    ChaosEvent,
    ChaosSchedule,
    CheckpointMonotonicity,
    CohortArrival,
    DiurnalTraffic,
    ExactlyOnceDelivery,
    FleetConfig,
    FleetSim,
    Freshness,
    JournalDurability,
    LakeConsistency,
    MetricsConservation,
    NoFullReingest,
    NoWedgedSubscribers,
    PhiBoundary,
    QueryArrival,
    QueryConsistency,
    QueryMix,
    ReplayStorm,
    TelemetryPhiBoundary,
    TraceIntegrity,
    WarmReplayIdentity,
)


def _tiny(tmp_path, name, seed=5, n_studies=3, traffic=None, chaos=None, **cfg_kw):
    cfg = FleetConfig(seed=seed, n_studies=n_studies, images_per_study=1, **cfg_kw)
    corpus = [f"SIM{i:04d}" for i in range(cfg.n_studies)]
    if traffic is None:
        traffic = [CohortArrival(t=0.0, study_id="IRB-T", accessions=tuple(corpus))]
    return FleetSim(cfg, traffic, tmp_path / f"{name}.jsonl", chaos)


# --------------------------------------------------------------- determinism
class TestReplayability:
    def test_same_seed_same_log_and_metrics(self, tmp_path):
        corpus = [f"SIM{i:04d}" for i in range(5)]
        traffic = BurstyTraffic(
            n_bursts=2, cohorts_per_burst=2, cohort_size=3
        ).schedule(corpus, seed=9)
        chaos = ChaosSchedule.seeded(9, horizon=400.0, corpus=corpus)

        def run(name):
            sim = _tiny(tmp_path, name, seed=9, n_studies=5,
                        traffic=traffic, chaos=chaos)
            return sim.run()

        r1, r2 = run("a"), run("b")
        assert r1.log_digest == r2.log_digest
        assert r1.metrics == r2.metrics
        assert r1.ok() and r2.ok()

    def test_different_seed_different_log(self, tmp_path):
        corpus = [f"SIM{i:04d}" for i in range(4)]
        t1 = ReplayStorm(base_size=3, n_replays=1, cohort_size=3).schedule(corpus, 1)
        t2 = ReplayStorm(base_size=3, n_replays=1, cohort_size=3).schedule(corpus, 2)
        r1 = _tiny(tmp_path, "s1", seed=1, n_studies=4, traffic=t1).run()
        r2 = _tiny(tmp_path, "s2", seed=2, n_studies=4, traffic=t2).run()
        assert r1.log_digest != r2.log_digest

    def test_event_log_is_json_serializable(self, tmp_path):
        sim = _tiny(tmp_path, "ser")
        sim.run()
        for line in sim.log.to_jsonl().splitlines():
            json.loads(line)


class TestTrafficModels:
    def test_schedules_are_deterministic_and_sorted(self):
        corpus = [f"A{i}" for i in range(10)]
        for model in (BurstyTraffic(), DiurnalTraffic(days=1), ReplayStorm()):
            s1, s2 = model.schedule(corpus, 42), model.schedule(corpus, 42)
            assert s1 == s2
            assert [a.t for a in s1] == sorted(a.t for a in s1)
            assert all(a.accessions for a in s1)

    def test_replay_storm_is_mostly_warm(self):
        corpus = [f"A{i}" for i in range(20)]
        arrivals = ReplayStorm(
            warm_fraction=0.9, base_size=10, n_replays=4, cohort_size=10
        ).schedule(corpus, 3)
        base = set(arrivals[0].accessions)
        for storm in arrivals[1:]:
            warm = sum(1 for a in storm.accessions if a in base)
            assert warm / len(storm.accessions) >= 0.8

    def test_query_mix_is_deterministic_and_sorted(self):
        corpus = [f"A{i}" for i in range(10)]
        s1 = QueryMix(n_queries=8).schedule(corpus, 42)
        s2 = QueryMix(n_queries=8).schedule(corpus, 42)
        assert s1 == s2  # predicates are frozen data, comparable wholesale
        assert [a.t for a in s1] == sorted(a.t for a in s1)
        assert s1 != QueryMix(n_queries=8).schedule(corpus, 43)

    def test_query_mix_selectivity_knobs(self):
        corpus = [f"A{i}" for i in range(4)]
        only_modality = QueryMix(
            n_queries=6, broad_fraction=0, year_fraction=0,
            and_fraction=0, negate_fraction=0, modality_fraction=1.0,
        ).schedule(corpus, 7)
        assert all(isinstance(a.query, Eq) for a in only_modality)
        only_broad = QueryMix(
            n_queries=4, broad_fraction=1.0, modality_fraction=0,
            year_fraction=0, and_fraction=0, negate_fraction=0,
        ).schedule(corpus, 7)
        assert all(isinstance(a.query, Range) for a in only_broad)


# ------------------------------------------------------------- query traffic
class TestQueryDrivenRuns:
    def test_query_sim_passes_all_invariants(self, tmp_path):
        corpus = [f"SIM{i:04d}" for i in range(6)]
        traffic = QueryMix(n_queries=5).schedule(corpus, seed=11)
        sim = _tiny(
            tmp_path, "qsim", seed=11, n_studies=6, traffic=traffic,
            modality=None,  # mixed modalities make the queries selective
            delivery_window=3600.0,
        )
        report = sim.run()
        assert report.ok(), [v.detail for v in report.violations]
        assert report.metrics["queries"] == 5
        assert len(sim.query_log) == 5
        assert sim.log.by_kind("query")  # admissions recorded in the log

    def test_query_sim_is_replayable(self, tmp_path):
        corpus = [f"SIM{i:04d}" for i in range(5)]
        traffic = QueryMix(n_queries=4).schedule(corpus, seed=3)

        def run(name):
            return _tiny(
                tmp_path, name, seed=3, n_studies=5, traffic=traffic,
                modality=None, delivery_window=3600.0,
            ).run()

        r1, r2 = run("qa"), run("qb")
        assert r1.log_digest == r2.log_digest
        assert r1.metrics == r2.metrics

    def test_mixed_query_and_cohort_traffic(self, tmp_path):
        corpus = [f"SIM{i:04d}" for i in range(4)]
        traffic = [
            CohortArrival(t=0.0, study_id="IRB-T", accessions=tuple(corpus[:2])),
            QueryArrival(t=60.0, study_id="IRB-T",
                         query=Range("study_date", 0, 99999999)),
        ]
        sim = _tiny(
            tmp_path, "mixed", n_studies=4, traffic=traffic,
            delivery_window=3600.0,
        )
        report = sim.run()
        assert report.ok(), [v.detail for v in report.violations]
        # the query saw the whole corpus; the two already-submitted
        # accessions ride the single-flight/journal path, never re-published
        _, qticket = sim.tickets[-1]
        assert len(qticket.hits) + len(qticket.coalesced) + len(qticket.cold) == 4
        assert qticket.selection_digest

    def test_reingest_chaos_keeps_query_consistency(self, tmp_path):
        corpus = [f"SIM{i:04d}" for i in range(4)]
        traffic = QueryMix(n_queries=4, mean_gap=120.0).schedule(corpus, seed=5)
        chaos = ChaosSchedule(
            [ChaosEvent(t=100.0, kind="reingest",
                        payload={"accession": "SIM0001"})]
        )
        sim = _tiny(
            tmp_path, "qreing", seed=5, n_studies=4, traffic=traffic,
            chaos=chaos, modality=None, delivery_window=3600.0,
        )
        report = sim.run()
        assert report.ok(), [v.detail for v in report.violations]


# --------------------------------------------------------------------- chaos
class TestChaosRuns:
    def test_crashes_and_stragglers_keep_invariants(self, tmp_path):
        corpus = [f"SIM{i:04d}" for i in range(4)]
        traffic = [
            CohortArrival(0.0, "IRB-C", tuple(corpus)),
            CohortArrival(200.0, "IRB-C", tuple(corpus)),  # warm replay
        ]
        chaos = ChaosSchedule([
            ChaosEvent(0.0, "set_crash_rate", {"rate": 0.4}),
            ChaosEvent(50.0, "set_straggler", {"rate": 0.3, "slow_factor": 30.0}),
            ChaosEvent(80.0, "lease_storm", {"visibility_timeout": 8.0, "duration": 60.0}),
        ])
        report = _tiny(
            tmp_path, "chaos", n_studies=4, traffic=traffic, chaos=chaos
        ).run()
        assert report.ok(), [v.detail for v in report.violations]
        assert report.metrics["crashes"] > 0
        assert report.metrics["processed"] == 4  # exactly once despite chaos

    def test_overlapping_lease_storms_restore_baseline(self, tmp_path):
        """Two overlapping storms must not leave the broker stuck on either
        storm's shrunken visibility timeout after both end."""
        corpus = [f"SIM{i:04d}" for i in range(3)]
        chaos = ChaosSchedule([
            ChaosEvent(0.0, "lease_storm", {"visibility_timeout": 5.0, "duration": 40.0}),
            ChaosEvent(10.0, "lease_storm", {"visibility_timeout": 12.0, "duration": 60.0}),
        ])
        sim = _tiny(tmp_path, "storms", n_studies=3, chaos=chaos)
        report = sim.run()
        assert report.ok(), [v.detail for v in report.violations]
        assert sim.broker.visibility_timeout == sim.config.visibility_timeout
        # mid-overlap, the first restore must not resurrect the baseline
        restores = sim.log.by_kind("chaos_restore")
        assert [r["storm_depth"] for r in restores] == [1, 0]
        assert restores[0]["visibility_timeout"] == 12.0
        assert restores[1]["visibility_timeout"] == sim.config.visibility_timeout

    def test_dead_letter_fails_ticket_without_wedging(self, tmp_path):
        """A poisoned accession exhausts max_deliveries=1 and dead-letters;
        its subscribers are failed out instead of waiting forever."""
        corpus = [f"SIM{i:04d}" for i in range(3)]
        chaos = ChaosSchedule([
            ChaosEvent(0.0, "crash_keys", {"accessions": ["SIM0001"]}),
        ])
        sim = _tiny(
            tmp_path, "dlq", n_studies=3, chaos=chaos, max_deliveries=1
        )
        report = sim.run()
        assert report.ok(), [v.detail for v in report.violations]
        assert report.metrics["dead_lettered"] == 1
        (_, ticket), = sim.tickets[:1]
        assert "SIM0001" in ticket.failed
        assert ticket.done()

    def test_reingest_and_ruleset_edit_keep_invariants(self, tmp_path):
        corpus = [f"SIM{i:04d}" for i in range(3)]
        traffic = [
            CohortArrival(0.0, "IRB-R", tuple(corpus)),
            CohortArrival(300.0, "IRB-R", tuple(corpus)),   # warm
            # a second research study arrives after the chaos window: its salt
            # differs, so it must recompute under the edited ruleset against
            # the re-ingested source (journal dedup does not apply across IRBs)
            CohortArrival(600.0, "IRB-R2", tuple(corpus)),
        ]
        chaos = ChaosSchedule([
            ChaosEvent(320.0, "reingest", {"accession": "SIM0000"}),
            ChaosEvent(340.0, "ruleset_edit", {"edit_id": 1}),
        ])
        sim = _tiny(tmp_path, "edit", n_studies=3, traffic=traffic, chaos=chaos)
        report = sim.run()
        assert report.ok(), [v.detail for v in report.violations]
        # the re-ingested + ruleset-edited accessions were genuinely recomputed
        assert report.metrics["processed"] > 3


# --------------------------------------------------- negative controls (5+)
class TestCheckersCatchInjectedViolations:
    """Each checker must flag a deliberately corrupted run."""

    def test_exactly_once_catches_double_count(self, tmp_path):
        sim = _tiny(tmp_path, "neg_eo")
        assert sim.run().ok()
        sim.pool._all_workers[0].processed += 1  # phantom second completion
        assert any(
            "processed" in v.detail for v in ExactlyOnceDelivery().check(sim)
        )

    def test_exactly_once_catches_missing_bucket_output(self, tmp_path):
        sim = _tiny(tmp_path, "neg_eo2")
        assert sim.run().ok()
        path = sim.dest.store.list("out/")[0]
        sim.dest.store.delete(path)  # lose a delivered instance
        assert any(
            "researcher bucket holds" in v.detail
            for v in ExactlyOnceDelivery().check(sim)
        )

    def test_phi_boundary_catches_planted_phi(self, tmp_path):
        sim = _tiny(tmp_path, "neg_phi")
        assert sim.run().ok()
        # an identified source instance leaks into the researcher bucket
        leaked = sim.source.get_study("SIM0000").datasets[0]
        sim.dest.store.put("out/IRB-T/LEAK/1", pickle.dumps(leaked))
        violations = PhiBoundary().check(sim)
        assert any("MRN" in v.detail or "patient name" in v.detail for v in violations)

    def test_warm_replay_catches_tampered_cache(self, tmp_path):
        corpus = [f"SIM{i:04d}" for i in range(3)]
        traffic = [
            CohortArrival(0.0, "IRB-T", tuple(corpus)),
            CohortArrival(120.0, "IRB-T", tuple(corpus)),  # served warm
        ]
        sim = _tiny(tmp_path, "neg_warm", traffic=traffic)
        assert sim.run().ok()
        warm_ticket = next(t for _, t in sim.tickets if t.hits and t.outputs)
        acc = next(iter(t for t in warm_ticket.hits if t in warm_ticket.outputs))
        warm_ticket.outputs[acc][0].elements["StudyID"] = "TAMPERED"
        assert any(
            acc in v.detail for v in WarmReplayIdentity().check(sim)
        )

    def test_autoscaler_accounting_catches_fudged_integral(self, tmp_path):
        sim = _tiny(tmp_path, "neg_cost")
        assert sim.run().ok()
        sim.pool.autoscaler.instance_seconds += 7.0  # cooked books
        assert any(
            "integral" in v.detail for v in AutoscalerAccounting().check(sim)
        )

    def test_no_wedged_subscribers_catches_ghost_registration(self, tmp_path):
        from repro.lake.planner import _InFlight

        sim = _tiny(tmp_path, "neg_wedge")
        assert sim.run().ok()
        # a registration that was never published: no broker copy, no journal
        # completion, no DLQ entry -> its subscribers would wait forever
        _, ticket = sim.tickets[0]
        pseudo = sim.service._studies[ticket.study_id]
        req = build_request(pseudo, "SIM0000", sim.mrns["SIM0000"])
        sim.service.planner._inflight["IRB-T/GHOST"] = _InFlight("GHOST", req, [ticket])
        # ...and a ticket pending on work with no registration at all
        ticket.pending.add("ORPHAN")
        violations = NoWedgedSubscribers().check(sim)
        assert any("IRB-T/GHOST" in v.detail for v in violations)
        assert any("ORPHAN" in v.detail and "wedged" in v.detail for v in violations)

    def test_lake_consistency_catches_lost_backing_blob(self, tmp_path):
        sim = _tiny(tmp_path, "neg_lake")
        assert sim.run().ok()
        key = sim.lake.keys()[0]
        sim.lake.backend.delete(key)  # index says present, backend lost it
        assert any(
            "no backing blob" in v.detail for v in LakeConsistency().check(sim)
        )

    def test_journal_durability_catches_unsynced_state(self, tmp_path):
        sim = _tiny(tmp_path, "neg_journal")
        assert sim.run().ok()
        # a completion the journal file knows about but the live dict lost:
        # a replay would resurrect work state the fleet never agreed on
        sim.journal._fh.write(
            json.dumps({"kind": "done", "key": "IRB-T/PHANTOM",
                        "manifest": {"request_id": "x", "entries": []}}) + "\n"
        )
        sim.journal._fh.flush()
        assert any(
            "PHANTOM" in v.detail for v in JournalDurability().check(sim)
        )

    def test_query_consistency_catches_tampered_selection(self, tmp_path):
        from dataclasses import replace

        corpus = [f"SIM{i:04d}" for i in range(4)]
        traffic = QueryMix(n_queries=3).schedule(corpus, seed=9)
        sim = _tiny(
            tmp_path, "neg_query", seed=9, n_studies=4, traffic=traffic,
            modality=None, delivery_window=3600.0,
        )
        assert sim.run().ok()
        qi = next(
            i for i, (_, sel, _) in enumerate(sim.query_log) if sel.accessions
        )
        arr, sel, snap = sim.query_log[qi]
        # drop one matched accession: the served selection no longer equals
        # the brute-force scan
        tampered = replace(
            sel,
            accessions=sel.accessions[1:],
            instance_counts={
                a: sel.instance_counts[a] for a in sel.accessions[1:]
            },
        )
        sim.query_log[qi] = (arr, tampered, snap)
        assert any(
            "brute-force" in v.detail for v in QueryConsistency().check(sim)
        )


# ------------------------------------------- continuous change-feed ingest
class TestFeedChaosRuns:
    """DESIGN.md §10: a live PACS change feed under full chaos — pooler
    crashes mid-batch, feed outages, duplicate/out-of-order delivery, and
    mid-flight re-ingests routed through the feed — must leave every
    invariant green, recover via checkpoint replay, and stay bit-replayable.
    Plus one negative control per new checker."""

    def _feed_sim(self, tmp_path, name, seed=11):
        corpus = [f"SIM{i:04d}" for i in range(6)]
        traffic = BurstyTraffic(
            n_bursts=2, cohorts_per_burst=2, cohort_size=3
        ).schedule(corpus, seed)
        chaos = ChaosSchedule.seeded(
            seed, 600.0, corpus,
            crash_events=1, reingests=2, lease_storms=1,
            pooler_crashes=2, feed_outages=1, feed_faults=1,
        )
        return _tiny(
            tmp_path, name, seed=seed, n_studies=6,
            traffic=traffic, chaos=chaos, feed_mutations=12,
        )

    def test_full_feed_chaos_keeps_all_invariants(self, tmp_path):
        sim = self._feed_sim(tmp_path, "feed_chaos")
        report = sim.run()
        assert report.ok(), [v.detail for v in report.violations]
        # every chaos family actually fired
        assert report.metrics["pooler_crashes"] == 2
        assert report.metrics["feed_outage_polls"] > 0
        assert report.metrics["feed_applied"] > 0
        # crash recovery redelivered the torn handoff and deduped by effect
        assert report.metrics["feed_redelivered"] >= 1
        # the final drain left the lake caught up with the PACS
        assert not sim.pooler.behind()
        assert sim.ingest_broker.empty()

    def test_feed_chaos_is_bit_replayable(self, tmp_path):
        r1 = self._feed_sim(tmp_path, "feed_rep_a").run()
        r2 = self._feed_sim(tmp_path, "feed_rep_b").run()
        assert r1.log_digest == r2.log_digest
        assert r1.metrics == r2.metrics

    def test_checkpoint_checker_catches_double_apply_and_phantom(self, tmp_path):
        sim = self._feed_sim(tmp_path, "neg_ckpt")
        assert sim.run().ok()
        # tamper the durable file, not the live dicts: the checker replays
        # the checkpoint from disk (same standard as the journal)
        sim.pooler.checkpoint._append(
            {"kind": "op", "seq": 1, "accession": "X", "etag": "",
             "op": "update", "outcome": "applied", "rows": 0}
        )
        sim.pooler.checkpoint._append(
            {"kind": "op", "seq": 9999, "accession": "X", "etag": "",
             "op": "update", "outcome": "applied", "rows": 0}
        )
        details = [v.detail for v in CheckpointMonotonicity().check(sim)]
        assert any("more than one outcome" in d for d in details)
        assert any("never-committed" in d for d in details)

    def test_freshness_checker_catches_stale_delivery(self, tmp_path):
        sim = self._feed_sim(tmp_path, "neg_fresh")
        assert sim.run().ok()
        # forge a delivery ordered after an acked mutation but carrying a
        # different source etag — exactly what the stale-byte fence prevents.
        # Pick an accession whose *latest* mutation is a live put (not a
        # delete), so the checker takes the stale-etag branch.
        latest = {m["accession"]: m for m in sim.mutation_log}
        mut = next(m for m in latest.values() if m["etag"])
        sim.delivery_log.append(
            {"seq": sim._order_seq + 1, "t": 999.0, "key": "IRB-X/FORGED",
             "accession": mut["accession"], "etag": "0" * 64}
        )
        assert any(
            "stale bytes delivered" in v.detail for v in Freshness().check(sim)
        )

    def test_freshness_checker_catches_post_delete_delivery(self, tmp_path):
        sim = self._feed_sim(tmp_path, "neg_freshdel")
        assert sim.run().ok()
        # deletes log etag=None; a delivery ordered after one is a resurrection
        sim.mutation_log.append(
            {"seq": sim._order_seq + 1, "t": 998.0,
             "accession": "SIM0000", "etag": None}
        )
        sim.delivery_log.append(
            {"seq": sim._order_seq + 2, "t": 999.0, "key": "IRB-X/GHOST",
             "accession": "SIM0000", "etag": "0" * 64}
        )
        assert any(
            "deleted" in v.detail for v in Freshness().check(sim)
        )

    def test_no_full_reingest_catches_catalog_rebuild(self, tmp_path):
        sim = self._feed_sim(tmp_path, "neg_rebuild")
        assert sim.run().ok()
        # a hidden full rebuild: re-index every resident study. The catalog's
        # cumulative row counter now exceeds what the applied mutations
        # account for, which is precisely the violation
        sim.source.attach_catalog(sim.catalog)
        assert any(
            "more work than the changed rows" in v.detail
            for v in NoFullReingest().check(sim)
        )


# -------------------------------------------------- step-driven pool parity
class TestStepDrivenPool:
    def test_step_loop_matches_drain(self, tmp_path):
        """Driving the pool via step()+manual clock must equal drain()."""
        from repro.core import DeidPipeline, TrustMode
        from repro.dicom.generator import StudyGenerator
        from repro.queueing import (
            Autoscaler, AutoscalerConfig, Broker, DeidWorker, Journal, WorkerPool,
        )
        from repro.queueing.server import DeidService
        from repro.storage.object_store import StudyStore
        from repro.utils.timing import SimClock

        def env(tag):
            clock = SimClock()
            gen = StudyGenerator(3)
            lake = StudyStore("lake")
            mrns = {}
            for i in range(3):
                s = gen.gen_study(f"P{i}", modality="CT", n_images=1)
                lake.put_study(f"P{i}", s)
                mrns[f"P{i}"] = s.mrn
            broker = Broker(clock, visibility_timeout=30.0)
            journal = Journal(tmp_path / f"{tag}.jsonl")
            service = DeidService(broker, lake, journal)
            service.register_study("IRB-S", TrustMode.POST_IRB)
            service.submit("IRB-S", list(mrns), mrns)
            pipeline = DeidPipeline(recompress=False)
            dest = StudyStore("researcher")
            pool = WorkerPool(
                broker,
                Autoscaler(broker, AutoscalerConfig(), clock),
                lambda wid: DeidWorker(wid, pipeline, lake, dest, journal),
            )
            return clock, broker, journal, pool

        clock_a, _, journal_a, pool_a = env("drain")
        report_a = pool_a.drain()

        clock_b, broker_b, journal_b, pool_b = env("step")
        t0 = clock_b.now()
        bytes_in = broker_b.stats().backlog_bytes
        while not broker_b.empty():
            busy = pool_b.step()
            clock_b.advance(max(busy, pool_b.tick_seconds))
        pool_b.finish()
        report_b = pool_b.report(t0, bytes_in)

        assert journal_a.completed_keys() == journal_b.completed_keys()
        assert report_a == report_b
        assert clock_a.now() == clock_b.now()


# ------------------------------------------- unknown-device detector traffic
class TestUnknownDeviceDetection:
    """DESIGN.md §9: novel (manufacturer, model) traffic must leave the fleet
    with zero surviving pixel PHI when the detector is on, the extended PHI
    invariant must catch the leak when it is off (negative control), and the
    unknown-device signal must surface in the fleet metrics."""

    def _run(self, tmp_path, name, mode, seed=5):
        cfg_kw = dict(
            modality="CT",
            images_per_study=3,
            unknown_device_rate=0.5,
            detector_mode=mode,
        )
        # _tiny pins images_per_study=1; build the config directly instead
        from repro.sim import FleetConfig, FleetSim

        cfg = FleetConfig(seed=seed, n_studies=6, **cfg_kw)
        corpus = [f"SIM{i:04d}" for i in range(cfg.n_studies)]
        traffic = [CohortArrival(t=0.0, study_id="IRB-T", accessions=tuple(corpus))]
        sim = FleetSim(cfg, traffic, tmp_path / f"{name}2.jsonl")
        return sim, sim.run()

    def test_detector_on_blanks_unknown_device_text(self, tmp_path):
        sim, report = self._run(tmp_path, "ud_on", "registry_first")
        assert report.ok(), report.violations
        assert report.metrics["unknown_device_lookups"] > 0
        assert report.metrics["detector_runs"] > 0
        assert report.metrics["detector_detected"] > 0

    def test_negative_control_detector_off_fails_phi(self, tmp_path):
        """Same seed, detector disabled: the synthesized unknown-device text
        survives into the researcher bucket and the extended PHI-boundary
        invariant must say so."""
        sim, report = self._run(tmp_path, "ud_off", "off")
        assert not report.ok()
        phi = [v for v in report.violations if v.checker == "phi_boundary"]
        assert phi and any("text band" in v.detail for v in phi)
        # the unknown lookups are still counted even with the detector off
        assert report.metrics["unknown_device_lookups"] > 0
        assert report.metrics["detector_runs"] == 0

    def test_text_band_audit_catches_planted_text(self, tmp_path):
        """Checker-level negative control: burn fresh glyph strokes into an
        already-delivered (clean) instance — the pixel-truth audit must flag
        it even though every registry region stays blanked."""
        sim = _tiny(tmp_path, "ud_plant")
        assert sim.run().ok()
        path = sim.dest.store.list("out/")[0]
        ds = pickle.loads(sim.dest.store.get(path))
        H, W = ds.pixels.shape
        ds.pixels[H // 2 : H // 2 + 12, ::3] = 4095  # max-contrast strokes
        sim.dest.store.put(path, pickle.dumps(ds))
        assert any(
            "text band" in v.detail for v in PhiBoundary().check(sim)
        )

    def test_unknown_traffic_is_bit_replayable(self, tmp_path):
        _, r1 = self._run(tmp_path, "ud_rep_a", "registry_first", seed=11)
        _, r2 = self._run(tmp_path, "ud_rep_b", "registry_first", seed=11)
        assert r1.log_digest == r2.log_digest
        assert r1.metrics == r2.metrics


# ------------------------------------------------- observability (DESIGN §11)
class TestTraceDeterminism:
    """The trace layer rides the same replayability contract as the event
    log: same seed -> bit-identical trace digest, and disabling tracing must
    change NOTHING about fleet behavior."""

    def _chaos_sim(self, tmp_path, name, seed=9, **cfg_kw):
        corpus = [f"SIM{i:04d}" for i in range(5)]
        traffic = BurstyTraffic(
            n_bursts=2, cohorts_per_burst=2, cohort_size=3
        ).schedule(corpus, seed=seed)
        chaos = ChaosSchedule.seeded(seed, horizon=400.0, corpus=corpus)
        return _tiny(tmp_path, name, seed=seed, n_studies=5,
                     traffic=traffic, chaos=chaos, **cfg_kw)

    def test_same_seed_same_trace_digest(self, tmp_path):
        r1 = self._chaos_sim(tmp_path, "tr_a").run()
        r2 = self._chaos_sim(tmp_path, "tr_b").run()
        assert r1.trace_digest == r2.trace_digest
        assert r1.ok() and r2.ok()

    def test_different_seed_different_trace_digest(self, tmp_path):
        r1 = self._chaos_sim(tmp_path, "tr_s1", seed=3).run()
        r2 = self._chaos_sim(tmp_path, "tr_s2", seed=4).run()
        assert r1.trace_digest != r2.trace_digest

    def test_trace_disabled_is_zero_behavior_change(self, tmp_path):
        r_on = self._chaos_sim(tmp_path, "tr_on", trace=True).run()
        r_off = self._chaos_sim(tmp_path, "tr_off", trace=False).run()
        # identical fleet behavior, bit for bit
        assert r_on.log_digest == r_off.log_digest
        assert r_on.metrics == r_off.metrics
        assert r_off.ok()
        # ...but the disabled tracer records nothing (digest of zero spans)
        import hashlib
        assert r_off.trace_digest == hashlib.sha256(b"").hexdigest()
        assert r_on.trace_digest != r_off.trace_digest

    def test_chaos_crash_leaves_auditable_retry_chain(self, tmp_path):
        from repro.obs.trace import trace_id_for

        chaos = ChaosSchedule([
            ChaosEvent(0.0, "crash_keys", {"accessions": ["SIM0001"]}),
        ])
        sim = _tiny(tmp_path, "tr_retry", chaos=chaos)
        report = sim.run()
        assert report.ok(), [v.detail for v in report.violations]
        key = "IRB-T/SIM0001"
        attempts = [s for s in sim.tracer.spans("worker.process")
                    if s.attrs.get("key") == key]
        assert len(attempts) >= 2
        by_attempt = {s.attrs["attempt"]: s for s in attempts}
        # attempt 1 crashed and the span recorded it; a later attempt finished
        assert by_attempt[1].attrs.get("error") == "WorkerCrash"
        assert any(s.attrs.get("ok") for s in attempts)
        # each attempt roots its own derived trace id, matching the broker's
        # lease events, and the redeliver event points at the NEXT attempt
        for s in attempts:
            assert s.trace_id == trace_id_for(key, s.attrs["attempt"])
        redelivers = [s for s in sim.tracer.spans("broker.redeliver")
                      if s.attrs.get("key") == key]
        assert any(s.trace_id == trace_id_for(key, 2) for s in redelivers)
        # child spans parent correctly under their attempt's root
        ok_attempt = next(s for s in attempts if s.attrs.get("ok"))
        children = [s for s in sim.tracer.spans()
                    if s.parent_id == ok_attempt.span_id]
        names = {s.name for s in children}
        assert {"worker.fetch", "worker.deid", "worker.deliver"} <= names
        assert all(s.trace_id == ok_attempt.trace_id for s in children)

    def test_trace_integrity_catches_open_and_dangling_spans(self, tmp_path):
        from repro.obs.trace import Span

        sim = _tiny(tmp_path, "neg_trace")
        assert sim.run().ok()
        # a span never closed...
        sim.tracer.span("left.open")
        # ...and a finished span whose parent does not exist in its trace
        sim.tracer.finished.append(Span(
            trace_id="rootdeadbeef", span_id="s99999999",
            parent_id="s88888888", name="orphan", t0=1.0, t1=2.0, seq=99999999,
        ))
        violations = TraceIntegrity().check(sim)
        assert any("still open" in v.detail for v in violations)
        assert any("dangling parent" in v.detail for v in violations)

    def test_trace_integrity_catches_untraced_completion(self, tmp_path):
        sim = _tiny(tmp_path, "neg_trace2")
        assert sim.run().ok()
        # forge a worker.process span's key away: the journal completion for
        # that key now has no trace
        span = next(s for s in sim.tracer.spans("worker.process")
                    if s.attrs.get("ok"))
        span.attrs["key"] = "IRB-T/FORGED"
        assert any(
            "no worker.process span" in v.detail
            for v in TraceIntegrity().check(sim)
        )


class TestTelemetryPhiBoundary:
    def test_redaction_on_passes_with_planted_phi(self, tmp_path):
        report = _tiny(
            tmp_path, "phi_red_on", plant_telemetry_phi=True
        ).run()
        assert report.ok(), [v.detail for v in report.violations]

    def test_negative_control_redaction_off_fails(self, tmp_path):
        report = _tiny(
            tmp_path, "phi_red_off",
            plant_telemetry_phi=True, telemetry_redact=False,
        ).run()
        tel = [v for v in report.violations
               if v.checker == "telemetry_phi_boundary"]
        assert tel and any(
            "MRN" in v.detail or "patient name" in v.detail for v in tel
        )

    def test_exported_spans_carry_no_free_text_even_unredacted_keys(self, tmp_path):
        """Every attribute the instrumentation emits under redaction must
        survive as an allowlisted key with an identifier-safe value — the
        exporter never has to fall back to ``[redacted]`` on a healthy run."""
        from repro.obs.export import REDACTED, Redactor
        import json as _json

        sim = _tiny(tmp_path, "phi_clean")
        assert sim.run().ok()
        red = Redactor()
        for s in sim.tracer.spans():
            for k, v in red.attrs(s.attrs).items():
                assert v != REDACTED, (s.name, k, s.attrs[k])


class TestMetricsConservation:
    def test_chaos_run_balances(self, tmp_path):
        corpus = [f"SIM{i:04d}" for i in range(5)]
        traffic = BurstyTraffic(
            n_bursts=2, cohorts_per_burst=2, cohort_size=3
        ).schedule(corpus, seed=7)
        chaos = ChaosSchedule.seeded(7, horizon=400.0, corpus=corpus)
        sim = _tiny(tmp_path, "cons_chaos", seed=7, n_studies=5,
                    traffic=traffic, chaos=chaos, feed_mutations=8)
        report = sim.run()
        assert report.ok(), [v.detail for v in report.violations]
        assert not MetricsConservation().check(sim)

    def test_negative_control_minted_broker_copy(self, tmp_path):
        sim = _tiny(tmp_path, "neg_cons1")
        assert sim.run().ok()
        sim.broker.counters.published += 1  # a copy that never existed
        assert any(
            "copy conservation" in v.detail
            for v in MetricsConservation().check(sim)
        )

    def test_negative_control_lost_planner_admission(self, tmp_path):
        sim = _tiny(tmp_path, "neg_cons2")
        assert sim.run().ok()
        sim.service.planner.stats.accessions += 1  # admission with no bin
        assert any(
            "planner admission" in v.detail
            for v in MetricsConservation().check(sim)
        )

    def test_negative_control_unhandled_delivery(self, tmp_path):
        sim = _tiny(tmp_path, "neg_cons3")
        assert sim.run().ok()
        sim.pool._all_workers[0].deduped += 1  # handling with no delivery
        assert any(
            "delivery accounting" in v.detail
            for v in MetricsConservation().check(sim)
        )


# ------------------------------------------ tamper-evident audit (DESIGN §14)
class TestAuditLedgerSim:
    """The audit ledger rides the replayability contract (same seed ->
    bit-identical chain digest), proves itself complete against journal /
    traces / event log / lake counters on full-chaos runs, and NULL_LEDGER
    changes nothing about fleet behavior. Plus one negative control per
    AuditCompleteness clause family, including the tamper control."""

    def _chaos_sim(self, tmp_path, name, seed=9, **cfg_kw):
        corpus = [f"SIM{i:04d}" for i in range(5)]
        traffic = BurstyTraffic(
            n_bursts=2, cohorts_per_burst=2, cohort_size=3
        ).schedule(corpus, seed=seed)
        chaos = ChaosSchedule.seeded(seed, horizon=400.0, corpus=corpus)
        return _tiny(tmp_path, name, seed=seed, n_studies=5,
                     traffic=traffic, chaos=chaos, **cfg_kw)

    def test_audit_completeness_green_under_chaos(self, tmp_path):
        sim = self._chaos_sim(tmp_path, "aud_chaos")
        report = sim.run()
        assert report.ok(), [v.detail for v in report.violations]
        assert report.audit["enabled"]
        assert report.audit["records"] > 0
        assert report.audit["by_kind"]["provenance"] >= 1
        assert report.audit["by_kind"]["delivery"] >= 1
        assert sim.ledger.verify() == []
        assert not AuditCompleteness().check(sim)

    def test_same_seed_same_audit_digest(self, tmp_path):
        r1 = self._chaos_sim(tmp_path, "aud_rep_a").run()
        r2 = self._chaos_sim(tmp_path, "aud_rep_b").run()
        assert r1.audit["digest"] == r2.audit["digest"]
        assert r1.audit["head"] == r2.audit["head"]
        assert r1.audit["by_kind"] == r2.audit["by_kind"]

    def test_different_seed_different_audit_digest(self, tmp_path):
        r1 = self._chaos_sim(tmp_path, "aud_s1", seed=3).run()
        r2 = self._chaos_sim(tmp_path, "aud_s2", seed=4).run()
        assert r1.audit["digest"] != r2.audit["digest"]

    def test_audit_disabled_is_zero_behavior_change(self, tmp_path):
        r_on = self._chaos_sim(tmp_path, "aud_on", audit=True).run()
        r_off = self._chaos_sim(tmp_path, "aud_off", audit=False).run()
        # identical fleet behavior, bit for bit — NULL_LEDGER is inert
        assert r_on.log_digest == r_off.log_digest
        assert r_on.metrics == r_off.metrics
        assert r_on.trace_digest == r_off.trace_digest
        assert r_off.ok()
        assert r_off.audit == {"enabled": False}
        assert r_on.audit["digest"] != ""

    def test_feed_chaos_audits_ingest_applies(self, tmp_path):
        corpus = [f"SIM{i:04d}" for i in range(6)]
        traffic = BurstyTraffic(
            n_bursts=2, cohorts_per_burst=2, cohort_size=3
        ).schedule(corpus, 11)
        chaos = ChaosSchedule.seeded(
            11, 600.0, corpus,
            crash_events=1, reingests=2, lease_storms=1, ruleset_edits=1,
            pooler_crashes=2, feed_outages=1, feed_faults=1,
        )
        sim = _tiny(tmp_path, "aud_feed", seed=11, n_studies=6,
                    traffic=traffic, chaos=chaos, feed_mutations=12)
        report = sim.run()
        assert report.ok(), [v.detail for v in report.violations]
        # every checkpointed outcome has its ledger record despite pooler
        # crashes rebuilding the applier mid-run
        applies = sim.ledger.records("ingest_apply")
        assert len(applies) == len(sim.applier.checkpoint.outcomes)
        # a ruleset edit mid-run leaves a policy_edit record after genesis
        assert report.audit["by_kind"]["policy_edit"] >= 2

    # -------------------------------------------------- negative controls
    def test_negative_control_tampered_ledger_fails_verify(self, tmp_path):
        """The tamper control: flip one payload byte mid-ledger — verify()
        must fail and the chain clause of AuditCompleteness must fire."""
        import json as _json

        from repro.audit.records import canonical_json

        sim = _tiny(tmp_path, "aud_tamper")
        assert sim.run().ok()
        lines = sim.ledger.path.read_text().splitlines()
        mid = len(lines) // 2
        rec = _json.loads(lines[mid])
        rec["t"] = float(rec["t"]) + 1.0  # mutate one field, keep the sha
        lines[mid] = canonical_json(rec)
        sim.ledger.path.write_text("\n".join(lines) + "\n")
        problems = sim.ledger.verify()
        assert any("sha mismatch" in p for p in problems), problems
        violations = AuditCompleteness().check(sim)
        assert any(
            v.detail.startswith("chain:") and "mutated" in v.detail
            for v in violations
        )

    def test_negative_control_deleted_record_breaks_chain(self, tmp_path):
        sim = _tiny(tmp_path, "aud_del")
        assert sim.run().ok()
        lines = sim.ledger.path.read_text().splitlines()
        del lines[len(lines) // 2]
        sim.ledger.path.write_text("\n".join(lines) + "\n")
        assert any(
            "chain:" in v.detail for v in AuditCompleteness().check(sim)
        )

    def test_negative_control_dropped_provenance_fires(self, tmp_path):
        """Workers skip their provenance/delivery records: the journal and
        event-log cross-checks must both fire."""
        sim = _tiny(tmp_path, "aud_drop", audit_drop_provenance=True)
        report = sim.run()
        aud = [v for v in report.violations if v.checker == "audit_completeness"]
        assert aud, [v.detail for v in report.violations]
        assert any(v.detail.startswith("journal:") for v in aud)
        assert any(v.detail.startswith("event log:") for v in aud)

    def test_negative_control_lake_counter_tamper(self, tmp_path):
        sim = _tiny(tmp_path, "aud_lake")
        assert sim.run().ok()
        sim.lake.stats.bytes_out += 1  # a byte out with no lake_hit record
        assert any(
            "lake:" in v.detail and "bytes_out" in v.detail
            for v in AuditCompleteness().check(sim)
        )

    def test_negative_control_dlq_tamper(self, tmp_path):
        chaos = ChaosSchedule([
            ChaosEvent(0.0, "crash_keys", {"accessions": ["SIM0001"]}),
        ])
        sim = _tiny(tmp_path, "aud_dlq", chaos=chaos, max_deliveries=1)
        assert sim.run().ok()
        sim.broker.dead_letter.pop()  # quietly un-dead-letter a message
        assert any(
            v.detail.startswith("dlq:") for v in AuditCompleteness().check(sim)
        )

    def test_negative_control_forged_ingest_record(self, tmp_path):
        from repro.audit.records import INGEST_APPLY

        sim = _tiny(tmp_path, "aud_ingest", feed_mutations=4)
        assert sim.run().ok()
        sim.ledger.append(
            INGEST_APPLY, feed_seq=999999, accession="FORGED", etag="e",
            op="update", outcome="applied", rows=1,
        )
        assert any(
            v.detail.startswith("ingest:")
            for v in AuditCompleteness().check(sim)
        )

    def test_disclosure_report_accounts_every_delivery(self, tmp_path):
        from repro.audit.report import DisclosureReport

        sim = self._chaos_sim(tmp_path, "aud_disc")
        assert sim.run().ok()
        rep = DisclosureReport.from_ledger(sim.ledger)
        total = sum(a.deliveries for a in rep.projects.values())
        assert total == len(sim.delivery_log)
        assert rep.ledger_digest == sim.ledger.digest()
