"""SLO engine, critical-path profiler, and health-controller loop
(DESIGN.md §13).

Covers the burn-rate state machine (fire needs BOTH windows, resolve on
short-window recovery), replay/digest determinism, histogram quantile
estimation, critical-path stage attribution, the closed burn→autoscaler
loop (strictly faster recovery with the signal on), the SloConformance
invariant (with tampering negative controls), and the migrated
ExecutorStats registry surface.
"""
import dataclasses

import pytest

from repro.core.batch import ExecutorStats
from repro.obs import (
    AlertEvent,
    BurnRule,
    CriticalPathProfiler,
    HealthController,
    Histogram,
    MetricsRegistry,
    Redactor,
    SloEngine,
    SloSpec,
    Tracer,
    default_burn_rules,
    derive_serve_observations,
    trace_id_for,
)
from repro.sim import (
    ChaosEvent,
    ChaosSchedule,
    CohortArrival,
    BurstyTraffic,
    FleetConfig,
    FleetSim,
    MetricsConservation,
    SloConformance,
)
from repro.utils.timing import SimClock


# one fast rule: long 60s, short 5s, burn >= 2 on both to fire
FAST = (BurnRule(60.0, 5.0, 2.0),)


def _engine(objective=0.5, threshold=1.0, rules=FAST, name="cold_serve"):
    return SloEngine([SloSpec(name, objective=objective, threshold=threshold,
                              rules=rules, budget_window=120.0)])


# ------------------------------------------------------------------ the engine
class TestSloEngine:
    def test_spec_validation(self):
        with pytest.raises(ValueError):
            SloSpec("x", objective=1.0)
        with pytest.raises(ValueError):
            SloSpec("x", rules=())
        with pytest.raises(ValueError):
            BurnRule(5.0, 60.0, 2.0)  # short > long
        with pytest.raises(ValueError):
            BurnRule(60.0, 5.0, 0.0)

    def test_good_bad_judgement_from_threshold(self):
        eng = _engine(threshold=1.0)
        assert eng.observe("cold_serve", t=0.0, value=0.5) is True
        assert eng.observe("cold_serve", t=1.0, value=1.5) is False
        with pytest.raises(ValueError):
            eng.observe("cold_serve", t=2.0)  # neither value nor good

    def test_fire_requires_both_windows(self):
        # objective 0.5 -> burn = bad_frac / 0.5. A single bad blip inside
        # the short window must NOT page while the long window is healthy.
        eng = _engine()
        for t in range(50):
            eng.observe("cold_serve", t=float(t), value=0.1)
        eng.observe("cold_serve", t=50.0, value=9.0)  # bad blip
        assert eng.evaluate(51.0) == []  # long window burn ~ 2/51 < 2
        assert eng.state("cold_serve") == "ok"

    def test_fire_and_resolve_sequence(self):
        eng = _engine()
        # sustained badness: every observation bad -> burn 2.0 on all windows
        for t in range(20):
            eng.observe("cold_serve", t=float(t), value=5.0)
        fired = eng.evaluate(20.0)
        assert [a.action for a in fired] == ["fire"]
        assert eng.state("cold_serve") == "burning"
        assert eng.evaluate(21.0) == []  # already active: no re-fire
        # recovery: good observations push the SHORT window under threshold
        for t in range(22, 40):
            eng.observe("cold_serve", t=float(t), value=0.1)
        resolved = eng.evaluate(40.0)
        assert [a.action for a in resolved] == ["resolve"]
        assert eng.state("cold_serve") == "ok"
        assert [a.action for a in eng.alerts] == ["fire", "resolve"]

    def test_observe_counts_batches(self):
        eng = _engine()
        eng.observe_counts("cold_serve", t=0.0, good=6, bad=4)
        assert eng.burn_rate("cold_serve", 60.0, 1.0) == pytest.approx(0.8)
        with pytest.raises(ValueError):
            eng.observe_counts("cold_serve", t=2.0, good=-1)
        with pytest.raises(KeyError):
            eng.observe_counts("nope", t=2.0, good=1)

    def test_observations_must_be_time_ordered(self):
        eng = _engine()
        eng.observe("cold_serve", t=10.0, value=0.1)
        with pytest.raises(ValueError):
            eng.observe("cold_serve", t=9.0, value=0.1)

    def test_budget_remaining(self):
        eng = _engine(objective=0.5)
        assert eng.budget_remaining("cold_serve", 0.0) == 1.0  # no traffic
        for t in range(10):
            eng.observe("cold_serve", t=float(t), value=0.1)
        assert eng.budget_remaining("cold_serve", 10.0) == 1.0
        for t in range(10, 20):
            eng.observe("cold_serve", t=float(t), value=9.0)
        # 10 bad of 20 total, 10 allowed -> budget exactly exhausted
        assert eng.budget_remaining("cold_serve", 20.0) == pytest.approx(0.0)

    def test_ensure_is_idempotent_and_first_wins(self):
        eng = _engine()
        tmpl = eng.specs["cold_serve"]
        ct = eng.ensure(dataclasses.replace(tmpl, name="cold_serve_CT"))
        again = eng.ensure(dataclasses.replace(tmpl, name="cold_serve_CT",
                                               objective=0.9))
        assert again is ct and again.objective == tmpl.objective

    def test_replay_reproduces_alerts_bit_for_bit(self):
        eng = _engine()
        for t in range(30):
            eng.observe("cold_serve", t=float(t), value=5.0 if t < 20 else 0.1)
            if t % 5 == 0:
                eng.evaluate(float(t))
        eng.evaluate(30.0)
        assert eng.alerts  # scenario produced transitions
        fresh = eng.replay()
        assert fresh.alerts == eng.alerts
        assert fresh.digest() == eng.digest()

    def test_tampered_alerts_break_replay(self):
        eng = _engine()
        for t in range(10):
            eng.observe("cold_serve", t=float(t), value=5.0)
        eng.evaluate(10.0)
        eng.alerts.append(AlertEvent(11.0, "cold_serve", 0, "resolve",
                                     "page", 0.0, 0.0))
        assert eng.replay().alerts != eng.alerts

    def test_digest_stable_and_sensitive(self):
        def build(bad_from):
            eng = _engine()
            for t in range(20):
                eng.observe("cold_serve", t=float(t),
                            value=5.0 if t >= bad_from else 0.1)
            eng.evaluate(20.0)
            return eng.digest()

        assert build(0) == build(0)
        assert build(0) != build(20)  # alerts vs none

    def test_registry_counters(self):
        reg = MetricsRegistry()
        eng = SloEngine([SloSpec("cold_serve", objective=0.5, threshold=1.0,
                                 rules=FAST)], registry=reg)
        for t in range(10):
            eng.observe("cold_serve", t=float(t), value=5.0)
        eng.evaluate(10.0)
        snap = reg.snapshot()
        assert snap["repro_slo_observations"] == 10
        assert snap["repro_slo_alerts_fired"] == 1

    def test_default_burn_rules_scale(self):
        prod = default_burn_rules()
        sim = default_burn_rules(1.0 / 60.0)
        assert prod[0].long_window == 3600.0 and prod[0].short_window == 300.0
        assert sim[0].long_window == pytest.approx(60.0)
        assert sim[1].long_window == pytest.approx(4320.0)


# ------------------------------------------------------- histogram quantiles
class TestHistogramQuantiles:
    def test_empty_series_is_none(self):
        h = Histogram("repro_test_lat")
        assert h.quantile(0.5) is None
        with pytest.raises(ValueError):
            h.quantile(1.5)

    def test_single_value_is_exact(self):
        h = Histogram("repro_test_lat")
        for _ in range(7):
            h.observe(0.42)
        for q in (0.0, 0.5, 0.95, 0.99, 1.0):
            assert h.quantile(q) == pytest.approx(0.42)
        snap = h.snapshot()[""]
        assert snap["p50"] == snap["p99"] == pytest.approx(0.42)
        assert snap["min"] == snap["max"] == pytest.approx(0.42)

    def test_error_bounded_by_bucket_width(self):
        h = Histogram("repro_test_lat", buckets=(1.0, 2.0, 4.0, 8.0))
        values = [0.5, 1.5, 1.7, 3.0, 3.5, 5.0, 6.0, 7.0]
        for v in values:
            h.observe(v)
        svals = sorted(values)
        for q in (0.5, 0.95, 0.99):
            est = h.quantile(q)
            exact = svals[min(len(svals) - 1, int(q * len(svals)))]
            # containing bucket has width <= 4 here
            assert abs(est - exact) <= 4.0

    def test_snapshot_keys_and_labels(self):
        h = Histogram("repro_test_lat")
        h.observe(0.2, modality="CT")
        snap = h.snapshot()
        (key,) = snap.keys()
        assert key == '{modality="CT"}'
        assert set(snap[key]) == {"count", "sum", "min", "max", "p50", "p95", "p99"}

    def test_series_surface_unchanged(self):
        # min/max are internals: series() (and registry snapshots built on
        # it) still expose exactly counts/sum/count
        h = Histogram("repro_test_lat")
        h.observe(0.2)
        assert set(h.series()[""]) == {"counts", "sum", "count"}


# hypothesis property tests ride alongside, skipping cleanly without it
try:
    from hypothesis import HealthCheck, given, settings, strategies as st

    _settings = settings(max_examples=50, deadline=None,
                         suppress_health_check=[HealthCheck.too_slow])

    class TestQuantileProperties:
        @given(values=st.lists(
            st.floats(min_value=0.0, max_value=100.0,
                      allow_nan=False, allow_infinity=False),
            min_size=1, max_size=60,
        ))
        @_settings
        def test_monotone_and_bounded(self, values):
            h = Histogram("repro_test_lat")
            for v in values:
                h.observe(v)
            p50, p95, p99 = (h.quantile(q) for q in (0.50, 0.95, 0.99))
            assert p50 <= p95 <= p99
            assert min(values) <= p50 and p99 <= max(values)

        @given(
            value=st.floats(min_value=0.0, max_value=100.0,
                            allow_nan=False, allow_infinity=False),
            n=st.integers(min_value=1, max_value=20),
        )
        @_settings
        def test_single_bucket_exactness(self, value, n):
            h = Histogram("repro_test_lat")
            for _ in range(n):
                h.observe(value)
            for q in (0.5, 0.95, 0.99):
                assert h.quantile(q) == pytest.approx(value)
except ImportError:  # pragma: no cover
    pass


# --------------------------------------------------------------- the profiler
def _scripted_serve(tracer, key, publish_t, lease_t, busy_s, modality="CT",
                    retry_t=None):
    """Emit the broker/worker span chain of one cold serve onto the tracer's
    clock (a SimClock the caller advances)."""
    clock = tracer.clock
    attempt = 1
    clock.advance(publish_t - clock.now())
    tracer.event("broker.publish", trace_id=trace_id_for(key, 1), key=key, attempt=1)
    if retry_t is not None:
        attempt = 2
        clock.advance(retry_t - clock.now())
        tracer.event("broker.redeliver", trace_id=trace_id_for(key, 2), key=key, attempt=2)
    tid = trace_id_for(key, attempt)
    clock.advance(lease_t - clock.now())
    tracer.event("broker.lease", trace_id=tid, key=key)
    with tracer.span("worker.process", trace_id=tid, key=key) as proc:
        with tracer.span("worker.fetch", accession="A1") as f:
            f.set(nbytes=100, instances=1, modality=modality)
        with tracer.span("worker.deid", busy_s=busy_s):
            pass
        with tracer.span("worker.deliver", datasets=1):
            pass
        proc.set(ok=True, busy_s=busy_s)
    tracer.event("broker.ack", trace_id=tid, key=key)


class TestCriticalPathProfiler:
    def test_stage_attribution(self):
        clock = SimClock()
        tracer = Tracer(clock)
        # publish t=0, redeliver t=10, lease t=25, busy 3s -> retry 10,
        # queue 15, deid 3
        _scripted_serve(tracer, "IRB/A1", 0.0, 25.0, 3.0, retry_t=10.0)
        prof = CriticalPathProfiler()
        assert prof.fold(tracer.spans()) == 1
        cells = prof.profile()["cold"]["CT"]
        assert cells["retry"]["total_s"] == pytest.approx(10.0)
        assert cells["queue"]["total_s"] == pytest.approx(15.0)
        assert cells["deid"]["total_s"] == pytest.approx(3.0)

    def test_fold_is_idempotent(self):
        clock = SimClock()
        tracer = Tracer(clock)
        _scripted_serve(tracer, "IRB/A1", 0.0, 5.0, 1.0)
        prof = CriticalPathProfiler()
        prof.fold(tracer.spans())
        d1 = prof.digest()
        assert prof.fold(tracer.spans()) == 0  # same spans: no double count
        assert prof.digest() == d1

    def test_non_ok_acks_are_skipped(self):
        clock = SimClock()
        tracer = Tracer(clock)
        key = "IRB/A1"
        tid = trace_id_for(key, 1)
        tracer.event("broker.publish", trace_id=tid, key=key, attempt=1)
        tracer.event("broker.lease", trace_id=tid, key=key)
        with tracer.span("worker.process", trace_id=tid, key=key) as proc:
            proc.set(deduped=True)  # dedup ack, not a serve
        tracer.event("broker.ack", trace_id=tid, key=key)
        prof = CriticalPathProfiler()
        assert prof.fold(tracer.spans()) == 0

    def test_exports_are_phi_safe_and_deterministic(self):
        clock = SimClock()
        tracer = Tracer(clock)
        _scripted_serve(tracer, "IRB/A1", 0.0, 5.0, 1.0, modality="DOE^JOHN")
        prof = CriticalPathProfiler()
        prof.fold(tracer.spans())
        folded = prof.export_folded(Redactor())
        assert "DOE^JOHN" not in folded  # ^ is outside the safe charset
        assert "[redacted]" in folded
        chrome = prof.to_chrome_trace(Redactor())
        assert "DOE^JOHN" not in str(chrome)
        assert any(e.get("name", "").startswith("profile.")
                   for e in chrome["traceEvents"])

    def test_top_stages_ranked(self):
        clock = SimClock()
        tracer = Tracer(clock)
        _scripted_serve(tracer, "IRB/A1", 0.0, 40.0, 2.0)  # queue-dominated
        prof = CriticalPathProfiler()
        prof.fold(tracer.spans())
        stages = prof.top_stages(2)
        assert stages[0][0] == "queue"


# ------------------------------------------------------- health + closed loop
class TestHealthController:
    def _burning_engine(self):
        eng = _engine()
        for t in range(10):
            eng.observe("cold_serve", t=float(t), value=5.0)
        eng.evaluate(10.0)
        assert eng.state("cold_serve") == "burning"
        return eng

    def test_pressure_from_latency_alerts_only(self):
        eng = self._burning_engine()
        hc = HealthController(eng)
        assert hc.pressure() == pytest.approx(2.0)  # 1 + 1 per alert
        # a burning non-latency SLO adds no pressure
        eng2 = SloEngine([SloSpec("dlq_rate", objective=0.5, kind="rate",
                                  rules=FAST)])
        for t in range(10):
            eng2.observe("dlq_rate", t=float(t), good=False)
        eng2.evaluate(10.0)
        assert eng2.state("dlq_rate") == "burning"
        assert HealthController(eng2).pressure() == 1.0

    def test_pressure_capped(self):
        eng = self._burning_engine()
        hc = HealthController(eng, boost_per_alert=10.0, max_pressure=3.0)
        assert hc.pressure() == 3.0

    def test_snapshot_and_summary(self):
        eng = self._burning_engine()
        hc = HealthController(eng)
        rep = hc.snapshot(10.0)
        assert rep.states == {"cold_serve": "burning"}
        assert rep.burning == ["cold_serve"]
        assert rep.active_alerts == ["cold_serve#0"]
        assert rep.budget_remaining["cold_serve"] < 1.0
        assert "1/1 SLOs burning" in rep.summary()
        d = rep.to_dict()
        assert d["states"]["cold_serve"] == "burning"

    def test_service_health_report_requires_attachment(self, tmp_path):
        sim = _sim(tmp_path, "svc")
        sim.run()
        rep = sim.service.health_report()
        assert set(rep.states) >= {"warm_hit", "cohort_e2e", "dlq_rate"}
        sim2 = _sim(tmp_path, "svc2", slo=False)
        sim2.run()
        with pytest.raises(RuntimeError):
            sim2.service.health_report()


# ------------------------------------------------------------ fleet scenarios
def _sim(tmp_path, name, seed=9, n_studies=5, traffic=None, chaos=None, **cfg):
    config = FleetConfig(seed=seed, n_studies=n_studies, images_per_study=1,
                         **cfg)
    corpus = [f"SIM{i:04d}" for i in range(n_studies)]
    if traffic is None:
        traffic = BurstyTraffic(
            n_bursts=2, cohorts_per_burst=2, cohort_size=3
        ).schedule(corpus, seed=seed)
    if chaos is None:
        chaos = ChaosSchedule.seeded(seed, horizon=400.0, corpus=corpus)
    return FleetSim(config, traffic, tmp_path / f"{name}.jsonl", chaos)


def _straggler_sim(tmp_path, name, autoscale):
    """One big cohort + a straggler storm from t=0: latency burns, the
    backlog alone justifies few instances (generous window)."""
    n = 10
    corpus = [f"SIM{i:04d}" for i in range(n)]
    cfg = FleetConfig(
        seed=3, n_studies=n, images_per_study=2,
        delivery_window=3600.0, worker_throughput=2e6,
        max_instances=8, slo_cold_threshold=20.0, slo_autoscale=autoscale,
    )
    traffic = [CohortArrival(t=0.0, study_id="IRB-B", accessions=tuple(corpus))]
    chaos = ChaosSchedule([ChaosEvent(
        t=0.0, kind="set_straggler",
        payload={"rate": 1.0, "slow_factor": 20.0},
    )])
    return FleetSim(cfg, traffic, tmp_path / f"{name}.jsonl", chaos)


class TestSloFleetIntegration:
    def test_same_seed_bit_identical_alerts_and_profile(self, tmp_path):
        r1 = _sim(tmp_path, "a").run()
        r2 = _sim(tmp_path, "b").run()
        assert r1.ok() and r2.ok()
        assert r1.slo["alert_digest"] == r2.slo["alert_digest"]
        assert r1.slo["profile_digest"] == r2.slo["profile_digest"]
        assert r1.slo["alerts_fired"] >= 1  # chaos run actually alerts
        assert r1.log_digest == r2.log_digest

    def test_slo_off_is_zero_behavior_change(self, tmp_path):
        s_on = _sim(tmp_path, "on")
        r_on = s_on.run()
        s_off = _sim(tmp_path, "off", slo=False)
        r_off = s_off.run()
        assert r_off.ok()
        assert r_off.slo == {}
        # identical log (minus the additive slo_alert stream) and metrics
        assert s_on.log.digest(exclude_kinds=("slo_alert",)) == s_off.log.digest()
        assert r_on.metrics == r_off.metrics
        assert r_on.trace_digest == r_off.trace_digest

    def test_alert_log_records_match_engine(self, tmp_path):
        sim = _sim(tmp_path, "alerts")
        sim.run()
        logged = sim.log.by_kind("slo_alert")
        assert len(logged) == len(sim.slo_engine.alerts)
        for rec, alert in zip(logged, sim.slo_engine.alerts):
            assert rec["slo"] == alert.slo
            assert rec["action"] == alert.action

    def test_freshness_slo_registered_with_feed(self, tmp_path):
        sim = _sim(tmp_path, "feed", feed_mutations=6)
        r = sim.run()
        assert r.ok()
        assert "ingest_freshness" in r.slo["states"]
        assert any(rec["slo"] == "ingest_freshness"
                   for rec in sim.slo_engine.obs_log)

    def test_slo_conformance_negative_control_tampered_alerts(self, tmp_path):
        sim = _sim(tmp_path, "tamper")
        r = sim.run()
        assert r.ok()
        sim.slo_engine.alerts.append(AlertEvent(
            9999.0, "cold_serve_CT", 0, "fire", "page", 9.0, 9.0))
        out = SloConformance().check(sim)
        assert out and any("replay" in v.detail for v in out)

    def test_slo_conformance_negative_control_tampered_observations(self, tmp_path):
        sim = _sim(tmp_path, "tamper2")
        r = sim.run()
        assert r.ok()
        # forge a cold-serve observation the span stream never saw
        cold = [rec for rec in sim.slo_engine.obs_log
                if rec["slo"].startswith("cold_serve")]
        assert cold
        cold[-1]["value"] = 12345.0
        out = SloConformance().check(sim)
        assert any("span stream" in v.detail for v in out)

    def test_cold_serve_observations_equal_span_derivation(self, tmp_path):
        sim = _sim(tmp_path, "derive")
        sim.run()
        derived = derive_serve_observations(sim.tracer.spans())
        observed = [rec for rec in sim.slo_engine.obs_log
                    if rec["slo"].startswith("cold_serve")]
        assert len(derived) == len(observed) > 0
        for (t, _key, lat), rec in zip(derived, observed):
            assert rec["t"] == pytest.approx(t)
            assert rec["value"] == pytest.approx(lat)


class TestBurnAutoscaleLoop:
    def test_burn_signal_strictly_shortens_recovery(self, tmp_path):
        r_on = _straggler_sim(tmp_path, "on", autoscale=True).run()
        r_off = _straggler_sim(tmp_path, "off", autoscale=False).run()
        assert r_on.ok() and r_off.ok()
        assert r_on.slo["alerts_fired"] >= 1
        assert r_off.slo["alerts_fired"] >= 1  # engine alerts either way
        # the closed loop buys strictly faster drain AND lower worst latency
        assert r_on.metrics["sim_minutes"] < r_off.metrics["sim_minutes"]
        assert r_on.metrics["max_latency_s"] < r_off.metrics["max_latency_s"]

    def test_burn_scale_up_events_only_with_signal(self, tmp_path):
        s_on = _straggler_sim(tmp_path, "ev_on", autoscale=True)
        s_on.run()
        s_off = _straggler_sim(tmp_path, "ev_off", autoscale=False)
        s_off.run()
        on_reasons = {e.reason for e in s_on.pool.autoscaler.events}
        off_reasons = {e.reason for e in s_off.pool.autoscaler.events}
        assert "burn-scale-up" in on_reasons
        assert "burn-scale-up" not in off_reasons


# -------------------------------------------------- ExecutorStats migration
class TestExecutorStatsMigration:
    def test_attribute_surface_preserved(self):
        st = ExecutorStats()
        st.instances += 13
        st.dispatches += 2
        st.bucket_keys.add((512, 512, "uint16", 4))
        st.bucket_keys.add((512, 512, "uint16", 4))  # set semantics
        st.padded_shapes.add((8, 512, 512, "uint16", 4))
        assert st.instances == 13 and st.dispatches == 2
        assert st.buckets == 1
        assert (512, 512, "uint16", 4) in st.bucket_keys
        assert len(st.padded_shapes) == 1

    def test_registry_backed_counters_and_gauges(self):
        reg = MetricsRegistry()
        st = ExecutorStats(reg)
        st.instances += 5
        st.detect_dispatches += 1
        st.bucket_keys.update({(1,), (2,), (3,)})
        snap = reg.snapshot()
        assert snap["repro_executor_instances"] == 5
        assert snap["repro_executor_detect_dispatches"] == 1
        assert snap["repro_executor_bucket_keys"] == 3

    def test_shared_registry_aggregates_across_executors(self):
        reg = MetricsRegistry()
        a, b = ExecutorStats(reg), ExecutorStats(reg)
        a.instances += 3
        b.instances += 4
        assert reg.value("repro_executor_instances") == 7

    def test_metrics_conservation_executor_negative_control(self, tmp_path):
        sim = _sim(tmp_path, "exec")
        r = sim.run()
        assert r.ok()
        # tamper the executor-side ledger: the worker-side deltas no longer
        # balance and the conservation checker must fire
        sim.pipeline.executor.stats.instances += 1
        out = MetricsConservation().check(sim)
        assert any("executor batch accounting" in v.detail for v in out)
