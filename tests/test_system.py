"""End-to-end behaviour tests for the paper's system: the full request
lifecycle across server -> queue -> autoscaled pool -> researcher bucket,
with the PHI boundary and reproducibility guarantees the paper claims."""
import json
import pickle

import pytest

from repro.core import DeidPipeline, TrustMode
from repro.dicom.generator import StudyGenerator
from repro.kernels.phi_detect.ops import audit_dataset
from repro.kernels.scrub import ops as scrub_ops
from repro.queueing import (
    Autoscaler,
    AutoscalerConfig,
    Broker,
    DeidWorker,
    FailureInjector,
    Journal,
    WorkerPool,
)
from repro.queueing.server import DeidService, RequestState
from repro.storage.object_store import StudyStore
from repro.utils.timing import SimClock


def _platform(tmp_path, n_studies=5, seed=31, use_kernel=False):
    clock = SimClock()
    gen = StudyGenerator(seed)
    lake = StudyStore("lake", key=b"at-rest")
    mrns = {}
    for i in range(n_studies):
        mod = ["CT", "US", "DX", "MR"][i % 4]
        s = gen.gen_study(f"SYS{i:04d}", modality=mod, n_images=2)
        lake.put_study(s.accession, s)
        mrns[s.accession] = s.mrn
    broker = Broker(clock, visibility_timeout=60)
    journal = Journal(tmp_path / "journal.jsonl")
    service = DeidService(broker, lake, journal)
    service.register_study("IRB-SYS", TrustMode.POST_IRB)
    dest = StudyStore("researcher")
    pipeline = DeidPipeline(blank_fn=scrub_ops.blank_fn if use_kernel else None, recompress=False)

    def mw(wid):
        return DeidWorker(wid, pipeline, lake, dest, journal)

    pool = WorkerPool(broker, Autoscaler(broker, AutoscalerConfig(), clock), mw)
    return clock, gen, lake, mrns, broker, journal, service, dest, pool


class TestFullLifecycle:
    def test_request_to_delivery(self, tmp_path):
        _, gen, lake, mrns, broker, journal, service, dest, pool = _platform(tmp_path)
        service.submit("IRB-SYS", list(mrns), mrns)
        report = pool.drain()
        assert report.processed == len(mrns)
        states = service.request_states("IRB-SYS")
        assert all(s is RequestState.DONE for s in states.values())
        # manifests are PHI-free
        manifest = journal.merged_manifest("IRB-SYS")
        blob = manifest.to_json()
        for acc, mrn in mrns.items():
            assert acc not in blob and mrn not in blob

    def test_phi_boundary_on_delivered_pixels(self, tmp_path):
        """Every delivered image passes the burned-in-text audit (the
        machine analogue of the paper's human review gate)."""
        _, gen, lake, mrns, broker, journal, service, dest, pool = _platform(
            tmp_path, n_studies=4, use_kernel=True
        )
        service.submit("IRB-SYS", list(mrns), mrns)
        pool.drain()
        checked = 0
        for path in dest.store.list("out/"):
            ds = pickle.loads(dest.store.get(path))
            if ds.pixels is not None:
                # audit_dataset thresholds at the stored bit depth (12-bit CT)
                assert not audit_dataset(ds), path
                checked += 1
        assert checked > 0

    def test_on_demand_reproducibility(self, tmp_path):
        """The paper's key property: re-running a request yields identical
        pseudonyms and identical transformations (manifest equality)."""
        _, gen, lake, mrns, broker, journal, service, dest, pool = _platform(tmp_path)
        accs = list(mrns)[:2]
        service.submit("IRB-SYS", accs, mrns)
        pool.drain()
        m1 = journal.merged_manifest("IRB-SYS").to_json()

        # a fresh platform instance over the same lake and study key
        clock2 = SimClock()
        broker2 = Broker(clock2, visibility_timeout=60)
        journal2 = Journal(tmp_path / "journal2.jsonl")
        service2 = DeidService(broker2, lake, journal2)
        service2.register_study("IRB-SYS", TrustMode.POST_IRB)
        dest2 = StudyStore("researcher2")
        pipeline = DeidPipeline(recompress=False)
        pool2 = WorkerPool(
            broker2,
            Autoscaler(broker2, AutoscalerConfig(), clock2),
            lambda wid: DeidWorker(wid, pipeline, lake, dest2, journal2),
        )
        service2.submit("IRB-SYS", accs, mrns)
        pool2.drain()
        m2 = journal2.merged_manifest("IRB-SYS").to_json()
        assert json.loads(m1)["counts"] == json.loads(m2)["counts"]
        e1 = {e["sop_uid_anon"]: e["tag_actions"] for e in json.loads(m1)["entries"]}
        e2 = {e["sop_uid_anon"]: e["tag_actions"] for e in json.loads(m2)["entries"]}
        assert e1 == e2  # identical pseudonymized UIDs and actions

    def test_separate_studies_cannot_join(self, tmp_path):
        """Two research studies over the same patient get unlinkable codes."""
        _, gen, lake, mrns, broker, journal, service, dest, pool = _platform(tmp_path)
        service.register_study("IRB-OTHER", TrustMode.POST_IRB)
        acc = list(mrns)[0]
        r1 = service.submit("IRB-SYS", [acc], mrns)[0]
        r2 = service.submit("IRB-OTHER", [acc], mrns)[0]
        assert r1.anon_accession != r2.anon_accession

    def test_chaos_does_not_lose_or_duplicate(self, tmp_path):
        clock, gen, lake, mrns, broker, journal, service, dest, pool = _platform(tmp_path, n_studies=8)
        pool.injector = FailureInjector(crash_rate=0.25, straggler_rate=0.2, slow_factor=50)
        service.submit("IRB-SYS", list(mrns), mrns)
        report = pool.drain()
        assert journal.completed_keys() == {f"IRB-SYS/{a}" for a in mrns}
        assert report.processed == len(mrns)
        # every delivered SOP uid is unique (no double delivery)
        uids = [p.rsplit("/", 1)[1] for p in dest.store.list("out/")]
        assert len(uids) == len(set(uids))
