"""Textdetect kernel parity + band-geometry unit tests (DESIGN.md §9).

The contract is *exact*: every statistic the Pallas kernel emits (row/column
projection profiles, max horizontal runs) must equal the numpy oracle bit for
bit, so the rectangles derived from them — and therefore delivered bytes —
are identical regardless of which path computed the profiles.
"""
import numpy as np
import pytest

from repro.detect.regions import (
    bands_from_hits,
    detect_bands_np,
    merge_rects,
    rects_from_bands,
)
from repro.kernels.textdetect import ops, ref

SHAPES = [(1, 32, 128), (2, 96, 256), (1, 97, 300), (3, 64, 513)]
DTYPES = [np.uint8, np.uint16]
TILE = (32, 128)


def _burn(imgs: np.ndarray, maxv: int) -> None:
    """Glyph-ish strokes: bright columns every 3 px over a row band."""
    imgs[:, 5:20, ::3] = maxv


class TestKernelParity:
    @pytest.mark.parametrize("shape", SHAPES)
    @pytest.mark.parametrize("dtype", DTYPES)
    def test_profiles_bit_identical(self, rng, shape, dtype):
        maxv = 255 if dtype == np.uint8 else 4095
        imgs = (rng.random(shape) * maxv * 0.5).astype(dtype)
        _burn(imgs, maxv)
        thresh = maxv * 0.6
        padded = ref.pad_to_tiles_np(imgs, TILE)
        r_ref, c_ref, runs_ref = ref.tile_profiles_ref(padded, thresh, TILE)
        r_k, c_k, runs_k = ops.tile_profiles(imgs, thresh=thresh, tile=TILE)
        np.testing.assert_array_equal(np.asarray(r_k), r_ref)
        np.testing.assert_array_equal(np.asarray(c_k), c_ref)
        np.testing.assert_array_equal(np.asarray(runs_k), runs_ref)

    @pytest.mark.parametrize("interpret", [True, None])
    def test_row_hits_bit_identical_both_paths(self, rng, interpret):
        """interpret=True is the CPU-interpreted kernel; None resolves to the
        backend default (compiled on accelerators) — both must match the
        oracle exactly."""
        imgs = (rng.random((2, 70, 200)) * 2000).astype(np.uint16)
        _burn(imgs, 4095)
        hits_k = ops.row_hit_profile(imgs, thresh=2457.0, tile=TILE, interpret=interpret)
        hits_r = ref.row_hits_np(imgs, 2457.0, TILE)
        np.testing.assert_array_equal(hits_k, hits_r)
        assert hits_r.dtype == np.int32 and hits_r.shape == (2, 70)

    def test_rect_masks_identical(self, rng):
        """Band rects derived from kernel profiles == rects from the oracle,
        and so are the blank masks they induce."""
        imgs = (rng.random((1, 90, 256)) * 1500).astype(np.uint16)
        _burn(imgs, 4095)
        imgs[0, 60:75, ::3] = 4095
        thresh = 4095 * 0.6
        hk = ops.row_hit_profile(imgs, thresh=thresh, tile=TILE)[0]
        hr = ref.row_hits_np(imgs, thresh, TILE)[0]
        rects_k = rects_from_bands(
            bands_from_hits(hk, 256, row_frac=0.04), 256
        )
        rects_r = rects_from_bands(
            bands_from_hits(hr, 256, row_frac=0.04), 256
        )
        assert rects_k == rects_r and rects_k
        mask_k = np.zeros((90, 256), bool)
        mask_r = np.zeros((90, 256), bool)
        for (x, y, w, h), m in ((r, mask_k) for r in rects_k):
            m[y : y + h, x : x + w] = True
        for (x, y, w, h), m in ((r, mask_r) for r in rects_r):
            m[y : y + h, x : x + w] = True
        np.testing.assert_array_equal(mask_k, mask_r)

    def test_padding_never_adds_hits(self, rng):
        """Zero padding can't binarize: profiles over real rows are the same
        whether the image arrives tile-aligned or ragged."""
        imgs = (rng.random((1, 64, 256)) * 4095).astype(np.uint16)
        ragged = imgs[:, :50, :200]
        hits_full = ref.row_hits_np(ragged, 1000.0, TILE)
        aligned = np.zeros((1, 64, 256), np.uint16)
        aligned[:, :50, :200] = ragged
        hits_pad = ref.row_hits_np(aligned, 1000.0, TILE)[:, :50]
        np.testing.assert_array_equal(hits_full, hits_pad)

    def test_max_run_separates_text_from_saturation(self):
        """Glyph strokes produce short runs; a saturated block produces one
        tile-wide run — the statistic tells them apart."""
        text = np.zeros((1, 32, 128), np.uint16)
        text[0, :, ::3] = 4095  # 1-px strokes
        sat = np.full((1, 32, 128), 4095, np.uint16)
        assert int(ref.max_run_np(text, 2457.0, TILE)[0]) == 1
        assert int(ref.max_run_np(sat, 2457.0, TILE)[0]) == 128

    def test_dtype_aware_threshold(self):
        assert ops.binarize_thresh(np.uint8) == 255 * 0.6
        assert ops.binarize_thresh(np.uint16) == 65535 * 0.6
        assert ops.binarize_thresh(np.uint16, max_value=4095) == 4095 * 0.6


class TestBands:
    def test_hot_rows_group_pad_and_merge(self):
        hits = np.zeros(100, np.int32)
        hits[10:20] = 50   # band 1
        hits[23:30] = 50   # band 2: padding fuses it with band 1
        hits[80:81] = 50   # single row: below min_rows
        bands = bands_from_hits(hits, 100, row_frac=0.04, min_rows=2, pad_rows=2)
        assert bands == [(8, 32)]

    def test_threshold_is_width_relative(self):
        hits = np.full(10, 5, np.int32)
        assert bands_from_hits(hits, 100, row_frac=0.04) == [(0, 10)]
        assert bands_from_hits(hits, 1000, row_frac=0.04) == []

    def test_clipping_at_frame_edges(self):
        hits = np.zeros(40, np.int32)
        hits[0:4] = 9
        hits[37:40] = 9
        bands = bands_from_hits(hits, 100, row_frac=0.04, min_rows=2, pad_rows=3)
        assert bands == [(0, 7), (34, 40)]

    def test_empty_profile_no_bands(self):
        assert bands_from_hits(np.zeros(64, np.int32), 128, row_frac=0.04) == []

    def test_rects_are_full_width(self):
        assert rects_from_bands([(4, 10), (20, 25)], 640) == [
            (0, 4, 640, 6),
            (0, 20, 640, 5),
        ]


class TestMergeRects:
    """Satellite: registry + detector unions must never double-blank a tile.
    Merging is conservative — the blanked pixel set is provably unchanged."""

    def test_dedupe_and_drop_empty(self):
        assert merge_rects([(0, 0, 10, 5), (0, 0, 10, 5), (3, 3, 0, 9), (1, 1, 4, 0)]) == [
            (0, 0, 10, 5)
        ]

    def test_contained_rect_dropped(self):
        assert merge_rects([(0, 0, 100, 50), (10, 10, 20, 20)]) == [(0, 0, 100, 50)]
        assert merge_rects([(10, 10, 20, 20), (0, 0, 100, 50)]) == [(0, 0, 100, 50)]

    def test_overlapping_stacked_bands_merge(self):
        # same column extent, overlapping rows -> exact union
        assert merge_rects([(0, 0, 640, 20), (0, 15, 640, 30)]) == [(0, 0, 640, 45)]

    def test_touching_bands_merge(self):
        # same column extent, touching rows (y2 == y0 + h0)
        assert merge_rects([(0, 0, 640, 20), (0, 20, 640, 10)]) == [(0, 0, 640, 30)]

    def test_side_by_side_blocks_merge(self):
        assert merge_rects([(0, 5, 30, 10), (30, 5, 20, 10)]) == [(0, 5, 50, 10)]

    def test_misaligned_overlap_not_merged(self):
        # union is not a rectangle: merging would over-blank -> keep both
        rects = [(0, 0, 100, 20), (50, 10, 100, 20)]
        out = merge_rects(rects)
        assert sorted(out) == sorted(rects)

    def test_chain_merges_to_fixpoint(self):
        out = merge_rects([(0, 0, 64, 8), (0, 8, 64, 8), (0, 16, 64, 8)])
        assert out == [(0, 0, 64, 24)]

    def test_blanked_set_invariant(self, rng):
        """Property on random rect soup: merged rects blank exactly the same
        pixels as the originals."""
        for trial in range(20):
            rects = [
                (
                    int(rng.integers(0, 50)),
                    int(rng.integers(0, 50)),
                    int(rng.integers(-2, 30)),
                    int(rng.integers(-2, 30)),
                )
                for _ in range(6)
            ]
            before = np.zeros((70, 70), bool)
            after = np.zeros((70, 70), bool)
            for x, y, w, h in rects:
                if w > 0 and h > 0:
                    before[y : y + h, x : x + w] = True
            merged = merge_rects(rects)
            for x, y, w, h in merged:
                after[y : y + h, x : x + w] = True
            np.testing.assert_array_equal(before, after)
            assert len(merged) <= len([r for r in rects if r[2] > 0 and r[3] > 0])


class TestDetectBands:
    def test_generator_text_is_found_and_blanking_clears_it(self, gen):
        from repro.core.scrub import numpy_blank

        study = gen.gen_study("TD-US", modality="US", n_images=1)
        ds = study.datasets[0]
        seeded = study.phi_rects[ds["SOPInstanceUID"]]
        H, W = ds.pixels.shape
        bands, rects = detect_bands_np(ds.pixels, thresh=255 * 0.6, row_frac=0.04)
        covered = np.zeros(H, bool)
        for y0, y1 in bands:
            covered[y0:y1] = True
        for x, y, w, h in seeded:
            assert covered[max(0, y) : min(H, y + h)].all(), (seeded, bands)
        clean = numpy_blank(ds.pixels, rects)
        assert detect_bands_np(clean, thresh=255 * 0.6, row_frac=0.04)[0] == []

    def test_clean_anatomy_is_quiet(self, gen):
        study = gen.gen_study("TD-CT", modality="CT", n_images=3)
        # CT: only every 17th slice carries the banner; slice 1 is clean
        ds = study.datasets[1]
        assert ds["SOPInstanceUID"] not in study.phi_rects
        bands, _ = detect_bands_np(ds.pixels, thresh=4095 * 0.6, row_frac=0.04)
        assert bands == []

    def test_precomputed_row_hits_short_circuit(self, rng):
        img = (rng.random((64, 128)) * 1000).astype(np.uint16)
        img[10:20, ::3] = 4095
        thresh = 4095 * 0.6
        hits = ref.row_hits_np(img[None], thresh, TILE)[0]
        direct = detect_bands_np(img, thresh=thresh, row_frac=0.04)
        via_hits = detect_bands_np(img, thresh=thresh, row_frac=0.04, row_hits=hits)
        assert direct == via_hits and direct[0]
