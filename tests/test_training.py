"""Training substrate: optimizer math, train-step convergence, microbatch
equivalence, checkpoint/restart exactness, serving engine behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.registry import get_arch
from repro.models import build_model
from repro.serving import Request, ServeEngine
from repro.training import (
    CheckpointManager,
    SyntheticTokenPipeline,
    cosine_schedule,
    make_train_step,
    train_state_init,
)
from repro.training.optimizer import adamw_init, adamw_update, clip_by_global_norm


class TestOptimizer:
    def test_adamw_reduces_quadratic(self):
        params = {"w": jnp.ones((8,), jnp.bfloat16) * 2.0}
        st = adamw_init(params)
        for _ in range(200):
            grads = {"w": st.master["w"]}  # grad of 0.5*||w||^2 wrt master
            params, st = adamw_update(grads, st, jnp.float32(0.05), weight_decay=0.0)
        assert float(jnp.abs(st.master["w"]).max()) < 0.3

    def test_clip_by_global_norm(self):
        g = {"a": jnp.full((4,), 10.0), "b": jnp.full((4,), 10.0)}
        clipped, gnorm = clip_by_global_norm(g, 1.0)
        new_norm = jnp.sqrt(sum(jnp.sum(x * x) for x in jax.tree.leaves(clipped)))
        assert float(new_norm) == pytest.approx(1.0, rel=1e-5)
        assert float(gnorm) == pytest.approx(np.sqrt(800.0), rel=1e-5)

    def test_cosine_schedule_shape(self):
        lr = cosine_schedule(1e-3, warmup=10, total=100)
        assert float(lr(jnp.int32(0))) == 0.0
        assert float(lr(jnp.int32(10))) == pytest.approx(1e-3, rel=1e-5)
        assert float(lr(jnp.int32(100))) == pytest.approx(0.0, abs=1e-9)
        assert float(lr(jnp.int32(55))) > float(lr(jnp.int32(90)))


class TestTrainStep:
    @pytest.fixture(scope="class")
    def setup(self):
        cfg = get_arch("qwen2-0.5b").reduced()
        model = build_model(cfg)
        state = train_state_init(model, jax.random.PRNGKey(0))
        pipe = SyntheticTokenPipeline(cfg, batch=4, seq=64, seed=3)
        return cfg, model, state, pipe

    def test_loss_decreases_on_fixed_batch(self, setup):
        cfg, model, state, pipe = setup
        step_fn = jax.jit(make_train_step(model, cosine_schedule(3e-3, 5, 200)))
        batch = jax.tree.map(jnp.asarray, pipe.get_batch(0))
        first = None
        for i in range(30):
            state, metrics = step_fn(state, batch)
            if first is None:
                first = float(metrics["loss"])
        last = float(metrics["loss"])
        assert last < first - 0.5, (first, last)  # memorizes the fixed batch

    def test_microbatch_equivalence(self, setup):
        cfg, model, _, pipe = setup
        batch = jax.tree.map(jnp.asarray, pipe.get_batch(1))
        sched = cosine_schedule(1e-3, 0, 100)
        s1 = train_state_init(model, jax.random.PRNGKey(1))
        s2 = train_state_init(model, jax.random.PRNGKey(1))
        st1, m1 = jax.jit(make_train_step(model, sched, microbatches=1))(s1, batch)
        st2, m2 = jax.jit(make_train_step(model, sched, microbatches=4))(s2, batch)
        assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-4)
        # updated master weights agree to accumulation tolerance
        d = jax.tree.map(
            lambda a, b: float(jnp.max(jnp.abs(a - b))), st1.opt.master, st2.opt.master
        )
        assert max(jax.tree.leaves(d)) < 5e-3

    def test_compressed_training_still_learns(self, setup):
        cfg, model, _, pipe = setup
        state = train_state_init(model, jax.random.PRNGKey(2), compression=True)
        step_fn = jax.jit(
            make_train_step(model, cosine_schedule(3e-3, 5, 200), compression=True)
        )
        batch = jax.tree.map(jnp.asarray, pipe.get_batch(0))
        first = None
        for _ in range(30):
            state, metrics = step_fn(state, batch)
            first = first if first is not None else float(metrics["loss"])
        assert float(metrics["loss"]) < first - 0.5

    def test_data_pipeline_host_sharding(self, setup):
        cfg, *_ = setup
        a = SyntheticTokenPipeline(cfg, 2, 32, seed=1, host_index=0, host_count=2).get_batch(0)
        b = SyntheticTokenPipeline(cfg, 2, 32, seed=1, host_index=1, host_count=2).get_batch(0)
        assert not np.array_equal(a["tokens"], b["tokens"])  # hosts see disjoint data
        a2 = SyntheticTokenPipeline(cfg, 2, 32, seed=1, host_index=0, host_count=2).get_batch(0)
        assert np.array_equal(a["tokens"], a2["tokens"])  # restart-deterministic


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        cfg = get_arch("qwen2-0.5b").reduced()
        model = build_model(cfg)
        state = train_state_init(model, jax.random.PRNGKey(0))
        mgr = CheckpointManager(tmp_path, keep_n=2)
        mgr.save(7, state, extra={"tokens_seen": 123})
        restored, step, extra = mgr.restore(state)
        assert step == 7 and extra["tokens_seen"] == 123
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_training_resumes_exactly(self, tmp_path):
        """ckpt at step 3, continue to 6 == train straight to 6."""
        cfg = get_arch("qwen2-0.5b").reduced()
        model = build_model(cfg)
        pipe = SyntheticTokenPipeline(cfg, 2, 32, seed=9)
        step_fn = jax.jit(make_train_step(model, cosine_schedule(1e-3, 0, 100)))

        def run(state, lo, hi):
            for i in range(lo, hi):
                state, m = step_fn(state, jax.tree.map(jnp.asarray, pipe.get_batch(i)))
            return state, m

        sA, _ = run(train_state_init(model, jax.random.PRNGKey(5)), 0, 6)

        sB, _ = run(train_state_init(model, jax.random.PRNGKey(5)), 0, 3)
        mgr = CheckpointManager(tmp_path)
        mgr.save(3, sB)
        sB2, step, _ = mgr.restore(sB)
        assert step == 3
        sB3, _ = run(sB2, 3, 6)
        for a, b in zip(jax.tree.leaves(sA.opt.master), jax.tree.leaves(sB3.opt.master)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)

    def test_retention_and_latest_pointer(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep_n=2)
        state = {"w": jnp.ones((3,))}
        for s in (1, 2, 3):
            mgr.save(s, state)
        names = sorted(p.name for p in tmp_path.iterdir() if p.name.startswith("step_"))
        assert names == ["step_00000002", "step_00000003"]
        assert mgr.latest_step() == 3

    def test_mismatched_template_rejected(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        mgr.save(1, {"w": jnp.ones((3,))})
        with pytest.raises(ValueError):
            mgr.restore({"w": jnp.ones((4,))})


class TestServeEngine:
    @pytest.fixture(scope="class")
    def engine(self):
        cfg = get_arch("qwen2-0.5b").reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        return ServeEngine(model, params, max_batch=4)

    def test_batched_requests_complete(self, engine):
        for i in range(6):  # 6 requests -> two batches of (4, 2)
            engine.submit(Request(f"r{i}", [1 + i, 2, 3], max_new_tokens=4))
        results = engine.run()
        assert len(results) == 6
        for r in results:
            assert len(r.tokens) == 4
            assert all(0 <= t < engine.model.cfg.vocab_size for t in r.tokens)

    def test_greedy_deterministic(self, engine):
        engine.submit(Request("a", [5, 6, 7], max_new_tokens=5))
        r1 = engine.run()[0]
        engine.submit(Request("b", [5, 6, 7], max_new_tokens=5))
        r2 = engine.run()[0]
        assert r1.tokens == r2.tokens
